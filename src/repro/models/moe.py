"""Mixture-of-Experts FFN with top-k routing (granite-moe, arctic).

Dense-einsum dispatch (capacity-less, "soft-drop" formulation): tokens ×
experts one-hot combine weights.  Expert weights live in a single stacked
(E, ...) tensor so expert parallelism is just a sharding rule on axis 0
(see repro.distributed.sharding).  The router's top-k comparison is a
*relational* SIMDRAM op class; with cfg.pum enabled the k=1 argmax mask
can be computed via bbop greater/max chains (demonstration path).

Aux load-balancing loss follows Switch/GShard: E·Σ_e f_e·p_e.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense_init

def moe_init(key, d: int, d_ff: int, n_experts: int, act: str, dtype) -> Params:
    kr, ku, kg, kd = jax.random.split(key, 4)
    import math
    std = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(kr, (d, n_experts), jnp.float32) * std).astype(jnp.float32),
        "up": (jax.random.normal(ku, (n_experts, d, d_ff), jnp.float32) * std).astype(dtype),
        "down": (jax.random.normal(kd, (n_experts, d_ff, d), jnp.float32)
                 * (1.0 / math.sqrt(d_ff))).astype(dtype),
    }
    if act == "swiglu":
        p["gate"] = (jax.random.normal(kg, (n_experts, d, d_ff), jnp.float32) * std).astype(dtype)
    return p


def moe_forward(
    p: Params, x: jax.Array, *, top_k: int, act: str
) -> Tuple[jax.Array, jax.Array]:
    """x (B,L,D) -> (out (B,L,D), aux_loss ())."""
    b, l, d = x.shape
    n_e = p["router"].shape[1]
    logits = (x.astype(jnp.float32) @ p["router"])          # (B,L,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)                 # (B,L,K)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)

    # combine weights (B,L,E): scatter top-k renormalized probs
    onehot = jax.nn.one_hot(topi, n_e, dtype=jnp.float32)    # (B,L,K,E)
    comb = jnp.einsum("blk,blke->ble", topv, onehot)

    # dense dispatch: every expert sees all tokens, masked-combined.
    # (dryrun/roofline-faithful: per-chip FLOPs match EP all-to-all dispatch
    # when experts are sharded; the hillclimb swaps this for real a2a.)
    from .quantized import effective_weight
    w_up = effective_weight(p["up"], x.dtype)
    w_down = effective_weight(p["down"], x.dtype)
    up = jnp.einsum("bld,edf->blef", x, w_up)
    if act == "swiglu":
        g = jnp.einsum("bld,edf->blef", x, effective_weight(p["gate"], x.dtype))
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    out = jnp.einsum("blef,efd->bled", h, w_down)
    out = jnp.einsum("bled,ble->bld", out, comb.astype(out.dtype))

    # aux load-balance loss
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))       # f_e
    frac_probs = jnp.mean(probs, axis=(0, 1))                # p_e
    aux = n_e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def moe_forward_grouped(
    p: Params, x: jax.Array, *, top_k: int, act: str,
    capacity_factor: float = 1.25, ep_hints: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based dispatch (gather/scatter form): tokens are routed to
    per-expert buffers of size C = cf·T·K/E — the EP formulation whose
    per-expert matmuls shard over the 'model' axis without the E× FLOPs
    blowup of the dense path.

    ep_hints pins the expert buffers to P("model", …) so dispatch/combine
    lower to all-to-all-sized transfers instead of GSPMD replicating the
    (E, C, d) buffers per chip (the arctic hillclimb: collective bytes per
    layer drop from O(E·C·d) to O(T·d·k/chips)).  Overflowed tokens add 0
    via a weight-masked scatter-add (no ragged +1 slot — keeps every dim
    mesh-divisible).
    """
    from repro.distributed.hints import hint

    b, l, d = x.shape
    t = b * l
    n_e = p["router"].shape[1]
    xt = x.reshape(t, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(capacity_factor * t * top_k / n_e))
    flat_e = topi.reshape(-1)                                  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, n_e, dtype=jnp.int32)      # (T*K,E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1    # queue rank
    keep = pos < cap
    slot = jnp.where(keep, pos, cap - 1)                       # clamp overflow
    buf_idx = flat_e * cap + slot
    tok_idx = jnp.repeat(jnp.arange(t), top_k)

    # dispatch: scatter-ADD with overflow contributions zeroed — kept slots
    # are written exactly once (queue ranks are unique per expert)
    payload = xt[tok_idx] * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((n_e * cap, d), xt.dtype)
    buf = buf.at[buf_idx].add(payload)
    eb = buf.reshape(n_e, cap, d)
    if ep_hints:
        eb = hint(eb, "model", None, None)

    from .quantized import effective_weight
    up = jnp.einsum("ecd,edf->ecf", eb, effective_weight(p["up"], eb.dtype))
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", eb, effective_weight(p["gate"], eb.dtype))
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    eout = jnp.einsum("ecf,efd->ecd", h, effective_weight(p["down"], eb.dtype))
    if ep_hints:
        eout = hint(eout, "model", None, None)
    eout = eout.reshape(n_e * cap, d)

    w = (topv.reshape(-1) * keep).astype(eout.dtype)
    out = jnp.zeros((t, d), eout.dtype)
    out = out.at[tok_idx].add(eout[buf_idx] * w[:, None])

    frac_tokens = jnp.mean(jax.nn.one_hot(topi, n_e, dtype=jnp.float32).sum(1), axis=0)
    aux = n_e * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
    return out.reshape(b, l, d), aux


def _grouped_local(p, xt, *, top_k, act, cap, e_lo, e_loc):
    """Token dispatch restricted to experts [e_lo, e_lo+e_loc) with LOCAL
    expert weights p (e_loc static; e_lo may be a traced axis_index).
    Tokens routed elsewhere contribute zero."""
    from .quantized import effective_weight

    t, d = xt.shape
    n_e = p["router"].shape[1]
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)
    mine = (flat_e >= e_lo) & (flat_e < e_lo + e_loc)
    local_e = jnp.clip(flat_e - e_lo, 0, e_loc - 1)
    onehot = jax.nn.one_hot(local_e, e_loc, dtype=jnp.int32) * mine[:, None]
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
    keep = mine & (pos < cap)
    slot = jnp.where(keep, pos, cap - 1)
    buf_idx = local_e * cap + slot
    tok_idx = jnp.repeat(jnp.arange(t), top_k)

    payload = xt[tok_idx] * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((e_loc * cap, d), xt.dtype).at[buf_idx].add(payload)
    eb = buf.reshape(e_loc, cap, d)

    up = jnp.einsum("ecd,edf->ecf", eb, effective_weight(p["up"], eb.dtype))
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", eb, effective_weight(p["gate"], eb.dtype))
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    eout = jnp.einsum("ecf,efd->ecd", h,
                      effective_weight(p["down"], eb.dtype)).reshape(e_loc * cap, d)

    w = (topv.reshape(-1) * keep).astype(eout.dtype)
    out = jnp.zeros((t, d), eout.dtype)
    out = out.at[tok_idx].add(eout[buf_idx] * w[:, None])

    frac_tokens = jnp.mean(jax.nn.one_hot(topi, n_e, dtype=jnp.float32).sum(1), axis=0)
    aux = n_e * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
    return out, aux


def moe_forward_ep(
    p: Params, x: jax.Array, *, top_k: int, act: str,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism via shard_map over the ambient mesh.

    Key idea: inside a TP block the activations are (logically) replicated
    across the `model` axis, so each model-rank can dispatch the SAME
    token set to its own E/TP experts with **zero communication**, compute
    locally, and emit a partial (T,d) output that a single psum over
    `model` combines.  Collectives per layer: one bf16 psum of the token
    activations — ~100× less than GSPMD's replicate-the-buffers fallback
    on arctic-480b (see EXPERIMENTS.md §Perf).

    Falls back to `moe_forward_grouped` when no mesh with a `model` axis
    is ambient (unit tests / single-host runs).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.hints import _ambient_mesh

    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_forward_grouped(p, x, top_k=top_k, act=act,
                                   capacity_factor=capacity_factor)
    b, l, d = x.shape
    n_e = p["router"].shape[1]
    tp = mesh.shape["model"]
    if n_e % tp != 0:
        return moe_forward_grouped(p, x, top_k=top_k, act=act,
                                   capacity_factor=capacity_factor)
    DATA = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_ok = b % max(1, _prod(mesh.shape[a] for a in DATA)) == 0 if DATA else True
    bspec = DATA if (DATA and batch_ok) else None

    e_loc = n_e // tp
    t_loc = (b // max(1, _prod(mesh.shape[a] for a in DATA))
             if bspec else b) * l
    cap = max(1, int(capacity_factor * t_loc * top_k / n_e))

    def local_fn(router, up, gate, down, x_loc):
        rank = jax.lax.axis_index("model")
        p_loc = {"router": router, "up": up, "down": down}
        if gate is not None:
            p_loc["gate"] = gate
        bl, ll, dd = x_loc.shape
        out, aux = _grouped_local(
            p_loc, x_loc.reshape(bl * ll, dd), top_k=top_k, act=act,
            cap=cap, e_lo=rank * e_loc, e_loc=e_loc)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, "model")
        return out.reshape(bl, ll, dd), aux

    has_gate = "gate" in p
    in_specs = (
        P(None, None),                      # router replicated
        P("model", None, None),             # up   (E on model)
        P("model", None, None) if has_gate else None,
        P("model", None, None),             # down
        P(bspec, None, None),               # x
    )
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(bspec, None, None), P()),
        check_rep=False,
    )
    gate = p.get("gate")
    # weights may be quantized dicts; shard_map specs must match pytrees
    def spec_like(w, spec):
        if isinstance(w, dict):
            return {k: spec if k == "w_q" else P("model", None) for k in w}
        return spec

    if any(isinstance(p[k], dict) for k in ("up", "down")):
        in_specs = (
            P(None, None),
            spec_like(p["up"], P("model", None, None)),
            spec_like(gate, P("model", None, None)) if has_gate else None,
            spec_like(p["down"], P("model", None, None)),
            P(bspec, None, None),
        )
        fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(bspec, None, None), P()), check_rep=False)
    return fn(p["router"], p["up"], gate, p["down"], x)


def _prod(it):
    out = 1
    for v in it:
        out *= v
    return out
