"""GPipe-style pipeline parallelism over a mesh axis (SPMD, shard_map).

Stages are contiguous layer groups whose stacked params shard over the
pipeline axis (one stage per rank).  The schedule is the classic GPipe
fill/drain: ``n_ticks = n_micro + n_stages − 1``; every rank computes every
tick (bubble compute is wasted but SPMD-uniform), activations hop one rank
per tick via ``ppermute``.  Differentiable end-to-end (ppermute has a
transpose rule), so ``jax.grad`` yields the reverse-schedule backward pass.

This complements the GSPMD DP/TP/EP modes: for very deep models on
multi-pod meshes, sharding layers over the ``pod`` axis replaces the
cross-pod FSDP all-gathers with point-to-point activation hops
(n_micro·(S−1) sends of one microbatch activation each — independent of
parameter count).  Used by tests/test_pipeline.py (8 virtual hosts) and
available to the dry-run via layers-over-pod configs.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(
    stage_fn: Callable,
    stacked_params,
    x: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "pod",
    n_micro: int = 4,
):
    """Run ``stage_fn(stage_params, h) -> h`` as a pipeline over `axis`.

    stacked_params: pytree with leading dim = n_stages (sharded over axis).
    x: (B, ...) batch input (replicated over `axis`); B % n_micro == 0.
    Returns the pipeline output (B, ...), replicated over `axis`.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro

    def device_fn(params_stage, x_full):
        # params_stage: this rank's stage params (leading dim 1 -> squeeze)
        params_stage = jax.tree.map(lambda t: t[0], params_stage)
        rank = jax.lax.axis_index(axis)
        stream = x_full.reshape(n_micro, mb, *x_full.shape[1:])
        n_ticks = n_micro + n_stages - 1

        def tick(recv, t):
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(rank == 0, stream[mb_idx], recv)
            y = stage_fn(params_stage, x_in)
            # hop: rank i -> i+1 (rank 0 receives zeros next tick)
            sent = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            return sent, y

        recv0 = jnp.zeros((mb, *x_full.shape[1:]), x_full.dtype)
        _, ys = jax.lax.scan(tick, recv0, jnp.arange(n_ticks))
        # last rank's outputs for tick t belong to microbatch t-(S-1)
        outs = ys[n_stages - 1:]                       # (n_micro, mb, ...)
        out = outs.reshape(b, *x_full.shape[1:])
        # broadcast the last rank's result to everyone (cheap for demos;
        # production keeps loss computation on the last stage instead)
        out = jax.lax.psum(
            jnp.where(rank == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out

    fn = shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stacked_params, x)


def split_stages(stacked_layer_params, n_stages: int):
    """(L, ...)-stacked layer params -> (S, L/S, ...) stage-stacked."""
    def re(t):
        l = t.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return t.reshape(n_stages, l // n_stages, *t.shape[1:])
    return jax.tree.map(re, stacked_layer_params)
