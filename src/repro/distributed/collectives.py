"""Collective/overlap helpers on top of GSPMD.

GSPMD already schedules TP collectives; these helpers add the knobs the
perf pass iterates on:

- constrain(): with_sharding_constraint shorthand using mesh axis names —
  used to force activation layouts at block boundaries (e.g. sequence-
  parallel norms) so XLA doesn't round-trip through replicated form;
- async_allreduce_scan(): microbatch gradient scan in which each
  microbatch's psum is issued inside the scan body rather than once at
  the end — XLA overlaps the previous microbatch's all-reduce with the
  next microbatch's backward (the classic DP overlap);
- pod_psum_compressed(): shard_map wrapper running the int8 compressed
  all-reduce of repro.train.compression across the 'pod' axis only.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def constrain(x: jax.Array, *spec) -> jax.Array:
    return jax.lax.with_sharding_constraint(x, P(*spec))


def sequence_parallel_norm(norm_fn: Callable, x: jax.Array,
                           seq_axis: str = "model") -> jax.Array:
    """Run a norm with the sequence dim sharded on `seq_axis` (SP): cheap
    elementwise work is distributed instead of replicated across TP ranks."""
    x = constrain(x, None, seq_axis, None)
    y = norm_fn(x)
    return constrain(y, None, seq_axis, None)


def async_allreduce_scan(grad_fn: Callable, params: Any, microbatches: Any,
                         axis_name: str) -> Any:
    """Gradient accumulation where each microbatch's contribution is
    psum'd inside the scan body (overlap-friendly schedule)."""

    def body(acc, mb):
        g = grad_fn(params, mb)
        g = jax.tree.map(lambda t: jax.lax.psum(t, axis_name), g)
        return jax.tree.map(jnp.add, acc, g), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    acc, _ = jax.lax.scan(body, zeros, microbatches)
    return acc


def pod_psum_compressed(mesh: Mesh, x: jax.Array) -> jax.Array:
    """int8-compressed all-reduce across pods (see train.compression)."""
    from repro.train.compression import compressed_psum

    if "pod" not in mesh.axis_names:
        return x
    inner_spec = P("pod", *([None] * (x.ndim - 1))) if x.shape[0] % mesh.shape["pod"] == 0 \
        else P(*([None] * x.ndim))

    fn = shard_map(
        lambda t: compressed_psum(t, "pod"),
        mesh=mesh,
        in_specs=(inner_spec,),
        out_specs=inner_spec,
        check_rep=False,
    )
    return fn(x)
