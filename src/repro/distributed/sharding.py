"""Sharding rules: 2-D (FSDP × TP) parameter layout + EP for MoE.

Mesh axes:
  single-pod: ("data", "model") = (16, 16)
  multi-pod : ("pod", "data", "model") = (2, 16, 16)

DATA = ("pod","data") — the combined FSDP/batch axes.  Every large matrix
is sharded BOTH ways: its "parallel" dim on `model` (tensor parallelism:
heads / ffn-hidden / vocab / experts) and the other dim on DATA (FSDP
storage sharding; GSPMD all-gathers just-in-time per layer under the
scan).  MoE expert stacks shard experts on `model` (expert parallelism).
Norm gains / scalar vectors replicate.

Every desired axis passes through a divisibility fit (`_fit`): if a dim
doesn't divide by the requested axis product, the rule degrades gracefully
(tuple → shorter tuple → replicated).  This is what lets ONE rule set
serve a batch-1 500k-decode cell and a batch-256 train cell, kv-head
counts below the TP degree, and hymba's 50 SSD heads, without per-arch
special cases.  Vocab dims are pre-padded in the model (config.vocab_padded).

These rules are pure functions path→PartitionSpec so the same tree serves
params, grads and both Adam moments; caches/batches have their own rule
sets.  All rules are exercised by every dry-run cell (launch/dryrun.py).
"""

from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """Construct a ``jax.sharding.AbstractMesh`` across JAX API versions.

    Newer JAX takes ``(axis_sizes, axis_names)``; the 0.4.x line takes a
    single ``((name, size), ...)`` shape tuple.  All sharding rules here
    only consume ``mesh.shape`` / ``mesh.axis_names``, which both forms
    provide.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return math.prod(mesh.shape[a] for a in axis)


def _fit(mesh: Mesh, dim: int, want: Axis) -> Axis:
    """Largest prefix of `want` whose size divides `dim` (None if none)."""
    if want is None:
        return None
    cands = [want]
    if isinstance(want, tuple):
        # try dropping leading axes: ('pod','data') -> ('data',)
        for i in range(1, len(want)):
            cands.append(want[i:])
    cands.append(None)
    for c in cands:
        if c is None:
            return None
        if dim % _axis_size(mesh, c) == 0:
            return c if not (isinstance(c, tuple) and len(c) == 1) else c[0]
    return None


def fit_spec(mesh: Mesh, shape: Sequence[int], *want: Axis) -> P:
    assert len(shape) == len(want), (shape, want)
    return P(*[_fit(mesh, d, w) for d, w in zip(shape, want)])


def _names(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return out


# parents whose dense 'w' has its OUTPUT dim model-parallel
_COL_PARALLEL = {"q", "k", "v", "up", "gate", "in_proj_z", "in_proj_xbc",
                 "out", "frontend_proj"}
# parents whose dense 'w' has its INPUT dim model-parallel
_ROW_PARALLEL = {"o", "down", "out_proj"}
# tiny projections that replicate their output dim
_REPLICATED_OUT = {"in_proj_dt"}


def param_spec(path, leaf, mesh: Mesh) -> P:
    names = _names(path)
    DATA = data_axes(mesh)
    stacked = any(n in ("blocks", "enc_blocks") for n in names)
    pre: Tuple[Axis, ...] = (None,) if stacked else ()
    shape = leaf.shape[len(pre):]
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    nd = len(shape)

    def fs(*want: Axis) -> P:
        return fit_spec(mesh, leaf.shape, *(pre + want))

    # int8-quantized weights: w_q follows the projection's 'w' rule; the
    # per-output-channel scale follows the weight's LAST-dim sharding
    if name in ("w_q", "scale"):
        proj = parent
        container = names[-3] if len(names) > 2 else ""
        if name == "scale":
            if proj in ("up", "gate") and (container == "moe" or nd == 2):
                return fs("model", None)          # (E, f)
            if proj == "down" and (container == "moe" or nd == 2):
                return fs("model", DATA)          # (E, d)
            if proj in _COL_PARALLEL:
                return fs("model")
            if proj in _ROW_PARALLEL:
                return fs(DATA)
            return fs(*([None] * nd))
        name, parent = (proj if nd == 3 else "w"), (container if nd == 3 else proj)

    if name == "emb":
        return fs("model", None)
    if name in ("g", "a_log", "d_skip", "dt_bias", "conv_b"):
        return fs(*([None] * nd))
    if name == "conv_w":
        return fs(None, "model")
    if name == "router":
        return fs(None, None)
    if parent == "moe" or nd == 3:
        # stacked expert weights (E, d, f) / (E, f, d): EP on model
        if name in ("up", "gate"):
            return fs("model", DATA, None)
        if name == "down":
            return fs("model", None, DATA)
        return fs("model", None, None)
    if nd == 2:
        if parent in _COL_PARALLEL:
            return fs(DATA, "model")
        if parent in _ROW_PARALLEL:
            return fs("model", DATA)
        if parent in _REPLICATED_OUT:
            return fs(DATA, None)
        return fs(*([None] * nd))
    if nd == 1:
        if parent in _COL_PARALLEL:
            return fs("model")
        return fs(None)
    return fs(*([None] * nd))


def param_spec_dp(path, leaf, mesh: Mesh) -> P:
    """Pure-FSDP (ZeRO-3) layout: no tensor parallelism — every param's
    largest dimension is sharded across ALL mesh axes; activations are
    batch-sharded across all axes too.

    Rationale (the small-model hillclimb): when d_model/TP-degree is tiny
    (seamless, internvl2, granite-moe), 2-D sharding turns every layer
    into sub-128 matmul shards plus per-layer TP collectives that dwarf
    compute; DP-only keeps matmuls whole and pays one gradient
    reduce-scatter per step.
    """
    names = _names(path)
    stacked = any(n in ("blocks", "enc_blocks") for n in names)
    pre: Tuple[Axis, ...] = (None,) if stacked else ()
    shape = leaf.shape[len(pre):]
    if not shape:
        return P(*pre)
    # embeddings / readout stay vocab-TP even under DP: ZeRO-3 would
    # re-gather the (often dominant) vocab table every step, while the
    # vocab-sharded form needs only an activation-sized psum
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    if name == "emb":
        return fit_spec(mesh, leaf.shape, *(pre + ("model", None)))
    if parent == "out" and name in ("w", "w_q"):
        return fit_spec(mesh, leaf.shape, *(pre + (None, "model")))
    if parent == "out" and name == "scale":
        return fit_spec(mesh, leaf.shape, *(pre + ("model",)))
    ALL = tuple(mesh.axis_names)
    # shard the largest divisible dim over all axes (degrade via _fit)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    want: list = [None] * len(shape)
    for i in order:
        ax = _fit(mesh, shape[i], ALL)
        if ax is not None and _axis_size(mesh, ax) == _axis_size(mesh, ALL):
            want[i] = ax
            break
    else:
        for i in order:                      # partial sharding fallback
            ax = _fit(mesh, shape[i], ALL)
            if ax is not None:
                want[i] = ax
                break
    return P(*(pre + tuple(want)))


def _strip_data_axes(spec: P, mesh: Mesh) -> P:
    """Replace DATA axes with replication (serve policy: weights stay
    resident, TP-sharded only — no per-step FSDP re-gathers at decode)."""
    drop = set(data_axes(mesh))

    def clean(s):
        if s is None:
            return None
        if isinstance(s, str):
            return None if s in drop else s
        kept = tuple(a for a in s if a not in drop)
        return kept if len(kept) > 1 else (kept[0] if kept else None)

    return P(*[clean(s) for s in spec])


def param_spec_dp2(path, leaf, mesh: Mesh) -> P:
    """ZeRO-2-style: small block weights fully REPLICATED (no per-layer
    re-gather in fwd/bwd), embeddings vocab-TP, optimizer state sharded
    (see opt_shardings).  Step pays one grad reduce + one param broadcast
    instead of 2× weight gathers + grad RS — a win when weights/chip are
    tiny (seamless: 0.35 GB replicated)."""
    names = _names(path)
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    stacked = any(n in ("blocks", "enc_blocks") for n in names)
    pre: Tuple[Axis, ...] = (None,) if stacked else ()
    nd = leaf.ndim - len(pre)
    if name == "emb":
        return fit_spec(mesh, leaf.shape, *(pre + ("model", None)))
    if parent == "out" and name in ("w", "w_q"):
        return fit_spec(mesh, leaf.shape, *(pre + (None, "model")))
    return P(*(pre + (None,) * nd))


def param_shardings(params_like: Any, mesh: Mesh, policy: str = "2d") -> Any:
    spec_fn = {"dp": param_spec_dp, "dp2": param_spec_dp2}.get(policy, param_spec)

    def one(path, leaf):
        spec = spec_fn(path, leaf, mesh)
        if policy == "serve":
            spec = _strip_data_axes(spec, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_like)


def opt_shardings(opt_state_like: Any, params_like: Any, mesh: Mesh,
                  policy: str = "2d") -> Any:
    """OptState(step, mu, nu): moments mirror the param layout — except
    under dp2 (ZeRO-2), where moments stay fully sharded while params
    replicate."""
    from repro.train.optimizer import OptState
    moment_policy = "dp" if policy == "dp2" else policy
    ps = param_shardings(params_like, mesh, moment_policy)
    rep = NamedSharding(mesh, P())
    return OptState(step=rep, mu=ps, nu=ps)


def batch_shardings(batch_like: Any, mesh: Mesh, policy: str = "2d") -> Any:
    DATA = (tuple(mesh.axis_names) if policy in ("dp", "dp2")
            else data_axes(mesh))

    def spec(path, leaf):
        want = (DATA,) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, fit_spec(mesh, leaf.shape, *want))

    return jax.tree_util.tree_map_with_path(spec, batch_like)


def cache_shardings(caches_like: Any, mesh: Mesh) -> Any:
    """Stacked caches (L, B, ...): batch on DATA, heads on model — with
    divisibility fallback (kv groups < TP degree shard head_dim instead)."""
    DATA = data_axes(mesh)

    def spec(path, leaf):
        names = _names(path)
        name = names[-1] if names else ""
        s = leaf.shape
        if name in ("k", "v"):                 # (L,B,S,G,hd)
            g_ax = _fit(mesh, s[3], "model")
            hd_ax = _fit(mesh, s[4], "model") if g_ax is None else None
            return NamedSharding(mesh, fit_spec(
                mesh, s, None, DATA, None, g_ax, hd_ax))
        if name in ("k_scale", "v_scale"):      # (L,B,S,G)
            g_ax = _fit(mesh, s[3], "model")
            return NamedSharding(mesh, fit_spec(mesh, s, None, DATA, None, g_ax))
        if name == "ssm":                       # (L,B,H,N,P)
            h_ax = _fit(mesh, s[2], "model")
            p_ax = _fit(mesh, s[4], "model") if h_ax is None else None
            return NamedSharding(mesh, fit_spec(
                mesh, s, None, DATA, h_ax, None, p_ax))
        if name == "conv":                      # (L,B,K-1,C)
            return NamedSharding(mesh, fit_spec(mesh, s, None, DATA, None, "model"))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree_util.tree_map_with_path(spec, caches_like)


def logits_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    DATA = data_axes(mesh)
    return NamedSharding(mesh, fit_spec(mesh, (batch, 1 << 30), DATA, "model"))


def vector_sharding(mesh: Mesh, batch: int) -> NamedSharding:
    DATA = data_axes(mesh)
    return NamedSharding(mesh, fit_spec(mesh, (batch,), DATA))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
