"""Distribution: sharding rules, collectives/overlap, pipeline stage."""
