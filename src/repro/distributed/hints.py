"""Mesh-optional sharding constraints.

Model code calls ``hint(x, *spec)`` to pin an intermediate's layout when
tracing under a mesh (dry-run / production) — and silently no-ops when
there is none (unit tests, CPU smoke runs).  This keeps layer code free of
mesh plumbing while letting the perf pass force activation layouts.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def _axis_size(mesh, s) -> int:
    if s is None:
        return 1
    if isinstance(s, str):
        return mesh.shape[s]
    out = 1
    for a in s:
        out *= mesh.shape[a]
    return out


def hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) iff a mesh with all the
    referenced axes is ambient; per-dim divisibility is checked and
    non-dividing axes degrade to replication.  Identity otherwise."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    fitted = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            fitted.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        if not set(axes) <= names or dim % _axis_size(mesh, axes) != 0:
            fitted.append(None)
        else:
            fitted.append(s)
    try:
        return jax.lax.with_sharding_constraint(x, P(*fitted))
    except Exception:
        return x


def hint_kv(x: jax.Array) -> jax.Array:
    """Layout hint for (B, S, G, hd) KV tensors/caches: batch on the DATA
    axes, kv-heads on `model` when they divide (else head_dim) — matching
    distributed.sharding.cache_shardings so decode steps never reshard the
    cache.  NOTE: in a sharding *constraint* None means REPLICATED, so the
    batch dim must be explicitly pinned to DATA (leaving it None forces a
    full-batch all-gather — measured 2×2.1 GB/layer on qwen2 decode)."""
    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data_ax = data if len(data) > 1 else (data[0] if data else None)
    g, hd = x.shape[-2], x.shape[-1]
    msize = mesh.shape["model"]
    lead = [None] * (x.ndim - 4)           # stacked-layer prefix if 5D
    if g % msize == 0:
        return hint(x, *lead, data_ax, None, "model", None)
    if hd % msize == 0:
        return hint(x, *lead, data_ax, None, None, "model")
    return hint(x, *lead, data_ax, None, None, None)
