"""Multi-device PuM execution: the chip's bank axis on the ``data`` mesh.

SIMDRAM's headline scaling knob is bank count — 16 banks replaying one
broadcast command stream reach 88× CPU throughput — and banks share
*nothing*: each owns its subarray states and (since PR 2) its own stacked
command tables.  That makes the chip-level replay embarrassingly parallel
along the bank axis, so the stacked

    states: (n_banks, n_subarrays, n_rows, n_words)
    tables: (n_banks, n_subarrays, n_cmds, 13)

arrays ``shard_map`` over a 1-D ``("data",)`` mesh: every device replays
its local bank slabs with exactly the same scan interpreter the
single-device path vmaps (:func:`repro.core.control_unit.chip_replay`),
so the two executors are bit-exact by construction — the paper's
multi-bank parallelism mapped onto real accelerator parallelism.

Divisibility follows :mod:`repro.distributed.sharding`'s ``fit_spec``
discipline: if the bank count doesn't divide the device count the spec
degrades to replication and the executor falls back to the jitted
vmap-over-banks path (also used on single-device hosts).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.control_unit import chip_batched_interpreter, chip_replay

from .sharding import fit_spec


def pum_mesh(n_banks: int, devices: Optional[Sequence] = None) -> Optional[Mesh]:
    """1-D ``("data",)`` mesh over the largest device prefix whose size
    divides ``n_banks`` (equal bank slabs per device).  ``None`` when
    only a single device would participate — the caller should use the
    vmap fallback instead of paying shard_map overhead for nothing."""
    devs = list(devices if devices is not None else jax.devices())
    size = max((d for d in range(1, len(devs) + 1) if n_banks % d == 0),
               default=1)
    if size <= 1:
        return None
    return Mesh(np.array(devs[:size]), ("data",))


@dataclass(frozen=True)
class ChipExecutor:
    """A compiled chip-replay callable plus how it partitions.

    ``run(states, tables)`` returns the executed states asynchronously
    (a jitted call either way); ``sharded`` tells whether bank slabs
    execute on different devices (shard_map) or one device vmaps them.
    """

    run: Callable
    mesh: Optional[Mesh]
    sharded: bool


def make_chip_executor(
    n_banks: int,
    mesh: Optional[Mesh] = None,
    use_shard_map: Optional[bool] = None,
) -> ChipExecutor:
    """Build the chip's replay executor.

    ``use_shard_map``: ``None`` auto-selects (shard_map whenever a
    multi-device mesh fits the bank axis), ``True`` requires it (raises
    if no mesh fits — the CI forced-device path uses this to guarantee
    the partitioned executor is actually exercised), ``False`` forces
    the single-device vmap fallback (the bit-exactness reference).
    """
    if use_shard_map is False:
        return ChipExecutor(chip_batched_interpreter(), None, False)
    if mesh is None:
        mesh = pum_mesh(n_banks)
    has_data = mesh is not None and "data" in tuple(mesh.axis_names)
    spec = fit_spec(mesh, (n_banks,), "data") if has_data else P(None)
    fits = has_data and spec[0] == "data" and mesh.shape["data"] > 1
    if not fits:
        if use_shard_map:
            raise ValueError(
                f"shard_map requested but no multi-device mesh fits "
                f"n_banks={n_banks} (devices={jax.device_count()})")
        return ChipExecutor(chip_batched_interpreter(), mesh, False)
    return ChipExecutor(_sharded_executor(mesh), mesh, True)


@functools.lru_cache(maxsize=None)
def _sharded_executor(mesh: Mesh) -> Callable:
    """One jitted shard_map executor per mesh — every chip on the same
    mesh shares it, so jit's shape cache (and the compiled executables)
    amortize across chips exactly like the vmap fallback's lru_cache."""
    from jax.experimental.shard_map import shard_map

    bank_spec = P("data", None, None, None)
    return jax.jit(shard_map(
        chip_replay, mesh=mesh,
        in_specs=(bank_spec, bank_spec), out_specs=bank_spec,
        check_rep=False))
