"""Multi-device PuM execution: bank and chip axes on real device meshes.

SIMDRAM's headline scaling knob is bank count — 16 banks replaying one
broadcast command stream reach 88× CPU throughput — and banks share
*nothing*: each owns its subarray states and (since PR 2) its own stacked
command tables.  That makes the chip-level replay embarrassingly parallel
along the bank axis, so the stacked

    states: (n_banks, n_subarrays, n_rows, n_words)
    tables: (n_banks, n_subarrays, n_cmds, 13)

arrays ``shard_map`` over a 1-D ``("data",)`` mesh: every device replays
its local bank slabs with exactly the same scan interpreter the
single-device path vmaps (:func:`repro.core.control_unit.chip_replay`),
so the two executors are bit-exact by construction — the paper's
multi-bank parallelism mapped onto real accelerator parallelism.

One level up, chips on a memory channel share nothing either (PULSAR's
scaling argument: the per-chip replay path is untouched; only the outer
dispatch widens), so the channel-level stack

    states: (n_chips, n_banks, n_subarrays, n_rows, n_words)
    tables: (n_chips, n_banks, n_subarrays, n_cmds, 13)

``shard_map``s over a 2-D ``("channel", "data")`` mesh — chip slabs
split across ``channel``, each chip's bank slabs across ``data`` — with
the same bit-exact jitted vmap fallback
(:func:`repro.core.control_unit.channel_replay`) on small hosts.

Divisibility follows :mod:`repro.distributed.sharding`'s ``fit_spec``
discipline: if an axis count doesn't divide the device count the spec
degrades to replication and the executor falls back to the jitted
vmap path (also used on single-device hosts).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.control_unit import (channel_batched_interpreter,
                                     channel_replay,
                                     chip_batched_interpreter, chip_replay,
                                     faulty_channel_batched_interpreter,
                                     faulty_channel_replay,
                                     faulty_chip_batched_interpreter,
                                     faulty_chip_replay,
                                     rank_batched_interpreter, rank_replay)

from .sharding import fit_spec


def _note_executor(kind: str, mesh: Optional[Mesh], sharded: bool) -> None:
    """Record which replay executor a tier got (shard_map vs the vmap
    fallback, and over how many devices) in the active trace, so a
    Perfetto timeline says how the replay actually partitioned."""
    from repro.core.telemetry import active_tracer
    tr = active_tracer()
    if tr is not None:
        tr.event("pum.executor", cat="plan", kind=kind, sharded=sharded,
                 devices=int(mesh.devices.size) if mesh is not None else 1)


def pum_mesh(n_banks: int, devices: Optional[Sequence] = None) -> Optional[Mesh]:
    """1-D ``("data",)`` mesh over the largest device prefix whose size
    divides ``n_banks`` (equal bank slabs per device).  ``None`` when
    only a single device would participate — the caller should use the
    vmap fallback instead of paying shard_map overhead for nothing."""
    devs = list(devices if devices is not None else jax.devices())
    size = max((d for d in range(1, len(devs) + 1) if n_banks % d == 0),
               default=1)
    if size <= 1:
        return None
    return Mesh(np.array(devs[:size]), ("data",))


@dataclass(frozen=True)
class ChipExecutor:
    """A compiled chip-replay callable plus how it partitions.

    ``run(states, tables)`` returns the executed states asynchronously
    (a jitted call either way); ``sharded`` tells whether bank slabs
    execute on different devices (shard_map) or one device vmaps them.
    """

    run: Callable
    mesh: Optional[Mesh]
    sharded: bool

    def describe(self) -> dict:
        """Flat summary for telemetry / benchmark artifacts."""
        return {
            "sharded": bool(self.sharded),
            "devices": int(self.mesh.devices.size) if self.mesh is not None
            else 1,
            "axes": list(self.mesh.axis_names) if self.mesh is not None
            else [],
        }


def make_chip_executor(
    n_banks: int,
    mesh: Optional[Mesh] = None,
    use_shard_map: Optional[bool] = None,
) -> ChipExecutor:
    """Build the chip's replay executor.

    ``use_shard_map``: ``None`` auto-selects (shard_map whenever a
    multi-device mesh fits the bank axis), ``True`` requires it (raises
    if no mesh fits — the CI forced-device path uses this to guarantee
    the partitioned executor is actually exercised), ``False`` forces
    the single-device vmap fallback (the bit-exactness reference).
    """
    if use_shard_map is False:
        _note_executor("chip", None, False)
        return ChipExecutor(chip_batched_interpreter(), None, False)
    if mesh is None:
        mesh = pum_mesh(n_banks)
    has_data = mesh is not None and "data" in tuple(mesh.axis_names)
    spec = fit_spec(mesh, (n_banks,), "data") if has_data else P(None)
    fits = has_data and spec[0] == "data" and mesh.shape["data"] > 1
    if not fits:
        if use_shard_map:
            raise ValueError(
                f"shard_map requested but no multi-device mesh fits "
                f"n_banks={n_banks} (devices={jax.device_count()})")
        _note_executor("chip", mesh, False)
        return ChipExecutor(chip_batched_interpreter(), mesh, False)
    _note_executor("chip", mesh, True)
    return ChipExecutor(_sharded_executor(mesh), mesh, True)


@functools.lru_cache(maxsize=None)
def _sharded_executor(mesh: Mesh) -> Callable:
    """One jitted shard_map executor per mesh — every chip on the same
    mesh shares it, so jit's shape cache (and the compiled executables)
    amortize across chips exactly like the vmap fallback's lru_cache."""
    from jax.experimental.shard_map import shard_map

    bank_spec = P("data", None, None, None)
    return jax.jit(shard_map(
        chip_replay, mesh=mesh,
        in_specs=(bank_spec, bank_spec), out_specs=bank_spec,
        check_rep=False))


def make_faulty_chip_executor(
    n_banks: int,
    mesh: Optional[Mesh] = None,
    use_shard_map: Optional[bool] = None,
) -> ChipExecutor:
    """Fault-injected twin of :func:`make_chip_executor`: the callable
    takes ``(states, tables, keys, stuck0, stuck1, dead, p_flip)`` and
    returns ``(executed states, per-subarray flip counts)``.  The fault
    operands are just more per-bank arrays, so they shard over the same
    ``data`` axis as the state slabs and the mesh-selection logic is
    identical."""
    if use_shard_map is False:
        _note_executor("chip.faulty", None, False)
        return ChipExecutor(faulty_chip_batched_interpreter(), None, False)
    if mesh is None:
        mesh = pum_mesh(n_banks)
    has_data = mesh is not None and "data" in tuple(mesh.axis_names)
    spec = fit_spec(mesh, (n_banks,), "data") if has_data else P(None)
    fits = has_data and spec[0] == "data" and mesh.shape["data"] > 1
    if not fits:
        if use_shard_map:
            raise ValueError(
                f"shard_map requested but no multi-device mesh fits "
                f"n_banks={n_banks} (devices={jax.device_count()})")
        _note_executor("chip.faulty", mesh, False)
        return ChipExecutor(faulty_chip_batched_interpreter(), mesh, False)
    _note_executor("chip.faulty", mesh, True)
    return ChipExecutor(_sharded_faulty_executor(mesh), mesh, True)


@functools.lru_cache(maxsize=None)
def _sharded_faulty_executor(mesh: Mesh) -> Callable:
    from jax.experimental.shard_map import shard_map

    bank_spec = P("data", None, None, None)
    unit2 = P("data", None, None)      # keys (banks, subs, 2), masks (banks, subs, words)
    unit1 = P("data", None)            # dead flags / flip counts (banks, subs)
    return jax.jit(shard_map(
        faulty_chip_replay, mesh=mesh,
        in_specs=(bank_spec, bank_spec, unit2, unit2, unit2, unit1, P()),
        out_specs=(bank_spec, unit1),
        check_rep=False))


# ---------------------------------------------------------------------------
# channel level: chips × banks on a 2-D ("channel", "data") mesh
# ---------------------------------------------------------------------------

def channel_mesh(n_chips: int, n_banks: int,
                 devices: Optional[Sequence] = None) -> Optional[Mesh]:
    """2-D ``("channel", "data")`` mesh for a channel's chip × bank grid.

    Picks the largest device grid ``(ch, da)`` with ``ch | n_chips`` and
    ``da | n_banks`` (equal chip slabs per ``channel`` row, equal bank
    slabs per ``data`` column), preferring to spend devices on the
    ``channel`` axis at equal total — chips are the outer scaling knob
    this tier adds.  ``None`` when only a single device would
    participate: the caller should use the vmap fallback instead of
    paying shard_map overhead for nothing."""
    devs = list(devices if devices is not None else jax.devices())
    best = (1, 1)
    for ch in range(1, len(devs) + 1):
        if n_chips % ch:
            continue
        da = max((d for d in range(1, len(devs) // ch + 1)
                  if n_banks % d == 0), default=1)
        if (ch * da, ch) > (best[0] * best[1], best[0]):
            best = (ch, da)
    ch, da = best
    if ch * da <= 1:
        return None
    return Mesh(np.array(devs[: ch * da]).reshape(ch, da),
                ("channel", "data"))


@dataclass(frozen=True)
class ChannelExecutor:
    """A compiled channel-replay callable plus how it partitions.

    ``run(states, tables)`` returns the executed (n_chips, n_banks,
    n_subarrays, n_rows, n_words) states asynchronously (a jitted call
    either way); ``sharded`` tells whether chip/bank slabs execute on
    different devices (2-D shard_map) or one device vmaps the whole
    stack.
    """

    run: Callable
    mesh: Optional[Mesh]
    sharded: bool

    def describe(self) -> dict:
        """Flat summary for telemetry / benchmark artifacts."""
        return {
            "sharded": bool(self.sharded),
            "devices": int(self.mesh.devices.size) if self.mesh is not None
            else 1,
            "axes": list(self.mesh.axis_names) if self.mesh is not None
            else [],
        }


def make_channel_executor(
    n_chips: int,
    n_banks: int,
    mesh: Optional[Mesh] = None,
    use_shard_map: Optional[bool] = None,
) -> ChannelExecutor:
    """Build the channel's replay executor.

    ``use_shard_map``: ``None`` auto-selects (shard_map whenever a
    multi-device ``("channel", "data")`` mesh fits the chip × bank
    grid), ``True`` requires it (raises if no mesh fits — the CI
    forced-device path uses this to guarantee the 2-D partitioned
    executor is actually exercised), ``False`` forces the single-device
    vmap fallback (the bit-exactness reference).
    """
    if use_shard_map is False:
        _note_executor("channel", None, False)
        return ChannelExecutor(channel_batched_interpreter(), None, False)
    if mesh is None:
        mesh = channel_mesh(n_chips, n_banks)
    has_axes = mesh is not None and {"channel", "data"} <= set(
        mesh.axis_names)
    spec = (fit_spec(mesh, (n_chips, n_banks), "channel", "data")
            if has_axes else P(None, None))
    fits = (has_axes and spec[0] == "channel" and spec[1] == "data"
            and mesh.devices.size > 1)
    if not fits:
        if use_shard_map:
            raise ValueError(
                f"shard_map requested but no multi-device (channel, data) "
                f"mesh fits n_chips={n_chips} × n_banks={n_banks} "
                f"(devices={jax.device_count()})")
        _note_executor("channel", mesh, False)
        return ChannelExecutor(channel_batched_interpreter(), mesh, False)
    _note_executor("channel", mesh, True)
    return ChannelExecutor(_sharded_channel_executor(mesh), mesh, True)


@functools.lru_cache(maxsize=None)
def _sharded_channel_executor(mesh: Mesh) -> Callable:
    """One jitted 2-D shard_map executor per mesh — every channel on the
    same mesh shares it, exactly like the chip-level executor cache."""
    from jax.experimental.shard_map import shard_map

    chip_spec = P("channel", "data", None, None, None)
    return jax.jit(shard_map(
        channel_replay, mesh=mesh,
        in_specs=(chip_spec, chip_spec), out_specs=chip_spec,
        check_rep=False))


def make_faulty_channel_executor(
    n_chips: int,
    n_banks: int,
    mesh: Optional[Mesh] = None,
    use_shard_map: Optional[bool] = None,
) -> ChannelExecutor:
    """Fault-injected twin of :func:`make_channel_executor`: the callable
    takes ``(states, tables, keys, stuck0, stuck1, dead, p_flip)`` and
    returns ``(executed states, per-subarray flip counts)``, with the
    fault operands sharded over the same ``("channel", "data")`` grid as
    the chip/bank slabs."""
    if use_shard_map is False:
        _note_executor("channel.faulty", None, False)
        return ChannelExecutor(
            faulty_channel_batched_interpreter(), None, False)
    if mesh is None:
        mesh = channel_mesh(n_chips, n_banks)
    has_axes = mesh is not None and {"channel", "data"} <= set(
        mesh.axis_names)
    spec = (fit_spec(mesh, (n_chips, n_banks), "channel", "data")
            if has_axes else P(None, None))
    fits = (has_axes and spec[0] == "channel" and spec[1] == "data"
            and mesh.devices.size > 1)
    if not fits:
        if use_shard_map:
            raise ValueError(
                f"shard_map requested but no multi-device (channel, data) "
                f"mesh fits n_chips={n_chips} × n_banks={n_banks} "
                f"(devices={jax.device_count()})")
        _note_executor("channel.faulty", mesh, False)
        return ChannelExecutor(
            faulty_channel_batched_interpreter(), mesh, False)
    _note_executor("channel.faulty", mesh, True)
    return ChannelExecutor(_sharded_faulty_channel_executor(mesh), mesh, True)


@functools.lru_cache(maxsize=None)
def _sharded_faulty_channel_executor(mesh: Mesh) -> Callable:
    from jax.experimental.shard_map import shard_map

    chip_spec = P("channel", "data", None, None, None)
    unit2 = P("channel", "data", None, None)   # keys / stuck masks
    unit1 = P("channel", "data", None)         # dead flags / flip counts
    return jax.jit(shard_map(
        faulty_channel_replay, mesh=mesh,
        in_specs=(chip_spec, chip_spec, unit2, unit2, unit2, unit1, P()),
        out_specs=(chip_spec, unit1),
        check_rep=False))


# ---------------------------------------------------------------------------
# rank level: channels × chips × banks on a 3-D ("rank", "channel", "data") mesh
# ---------------------------------------------------------------------------

def rank_mesh(n_channels: int, n_chips: int, n_banks: int,
              devices: Optional[Sequence] = None) -> Optional[Mesh]:
    """3-D ``("rank", "channel", "data")`` mesh for a rank's channel ×
    chip × bank grid.

    Picks the largest device grid ``(ra, ch, da)`` with ``ra |
    n_channels``, ``ch | n_chips`` and ``da | n_banks`` (equal channel
    slabs per ``rank`` plane, equal chip slabs per ``channel`` row,
    equal bank slabs per ``data`` column), preferring to spend devices
    on the outer axes at equal total — channels are the outermost
    scaling knob this tier adds.  ``None`` when only a single device
    would participate: the caller should use the vmap fallback instead
    of paying shard_map overhead for nothing."""
    devs = list(devices if devices is not None else jax.devices())
    best = (1, 1, 1)
    for ra in range(1, len(devs) + 1):
        if n_channels % ra:
            continue
        for ch in range(1, len(devs) // ra + 1):
            if n_chips % ch:
                continue
            da = max((d for d in range(1, len(devs) // (ra * ch) + 1)
                      if n_banks % d == 0), default=1)
            cand = (ra, ch, da)
            if ((ra * ch * da, ra, ch)
                    > (best[0] * best[1] * best[2], best[0], best[1])):
                best = cand
    ra, ch, da = best
    if ra * ch * da <= 1:
        return None
    return Mesh(np.array(devs[: ra * ch * da]).reshape(ra, ch, da),
                ("rank", "channel", "data"))


def make_rank_executor(
    n_channels: int,
    n_chips: int,
    n_banks: int,
    mesh: Optional[Mesh] = None,
    use_shard_map: Optional[bool] = None,
) -> ChannelExecutor:
    """Build the rank's replay executor (the :class:`ChannelExecutor`
    shape fits unchanged — ``run(states, tables)`` over one more leading
    axis).

    ``use_shard_map``: ``None`` auto-selects (shard_map whenever a
    multi-device ``("rank", "channel", "data")`` mesh fits the channel ×
    chip × bank grid), ``True`` requires it (raises if no mesh fits —
    the CI forced-device path uses this to guarantee the 3-D partitioned
    executor is actually exercised), ``False`` forces the single-device
    vmap fallback (the bit-exactness reference).
    """
    if use_shard_map is False:
        _note_executor("rank", None, False)
        return ChannelExecutor(rank_batched_interpreter(), None, False)
    if mesh is None:
        mesh = rank_mesh(n_channels, n_chips, n_banks)
    has_axes = mesh is not None and {"rank", "channel", "data"} <= set(
        mesh.axis_names)
    spec = (fit_spec(mesh, (n_channels, n_chips, n_banks),
                     "rank", "channel", "data")
            if has_axes else P(None, None, None))
    fits = (has_axes and spec[0] == "rank" and spec[1] == "channel"
            and spec[2] == "data" and mesh.devices.size > 1)
    if not fits:
        if use_shard_map:
            raise ValueError(
                f"shard_map requested but no multi-device "
                f"(rank, channel, data) mesh fits n_channels={n_channels} "
                f"× n_chips={n_chips} × n_banks={n_banks} "
                f"(devices={jax.device_count()})")
        _note_executor("rank", mesh, False)
        return ChannelExecutor(rank_batched_interpreter(), mesh, False)
    _note_executor("rank", mesh, True)
    return ChannelExecutor(_sharded_rank_executor(mesh), mesh, True)


@functools.lru_cache(maxsize=None)
def _sharded_rank_executor(mesh: Mesh) -> Callable:
    """One jitted 3-D shard_map executor per mesh — every rank on the
    same mesh shares it, exactly like the channel-level executor cache."""
    from jax.experimental.shard_map import shard_map

    channel_spec = P("rank", "channel", "data", None, None, None)
    return jax.jit(shard_map(
        rank_replay, mesh=mesh,
        in_specs=(channel_spec, channel_spec), out_specs=channel_spec,
        check_rep=False))
