"""LeNet-5 quantized inference on SIMDRAM (paper §5 app kernel).

Conv/fc MACs are charged analytically (bit-serial mul+add μPrograms);
every elementwise stage runs as a dispatched bbop queue — each conv
block's ReLU and 2×2 max-pool fuse into ONE
:func:`~repro.apps.nn_layers.relu_maxpool2x2_pum` ``Ref`` chain, fc
ReLUs go through :func:`~repro.apps.nn_layers.relu_pum` — so the whole
network exercises the selected backend ladder rung.  Each stage
verifies against a numpy oracle with a raising check.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.isa import SimdramDevice

from .nn_layers import (LayerCost, _pool_oracle, conv2d_int, dense_int,
                        relu_maxpool2x2_pum, relu_pum)
from .runtime import resolve_device, verify


def run(device: SimdramDevice | None = None,
        backend: str = "bitplane",
        seed: int = 0,
        elementwise_pum: bool = True,
        conv_channels: Tuple[int, ...] = (6, 16),
        fc_dims: Tuple[int, ...] = (120, 84, 10)) -> Dict:
    dev = resolve_device(device, backend)
    rng = np.random.default_rng(seed)

    x = rng.integers(0, 64, size=(1, 28, 28)).astype(np.int64)
    total_macs = 0

    def conv_block(x, c_out, k, pad):
        nonlocal total_macs
        c_in = x.shape[0]
        w = rng.integers(-8, 8, size=(c_out, c_in, k, k)).astype(np.int64)
        y = conv2d_int(x, w, pad=pad)
        macs = int(np.prod(y.shape)) * c_in * k * k
        total_macs += macs
        LayerCost("conv", macs, int(np.prod(y.shape))).account_matmul(dev, 8)
        y = np.clip(y >> 4, -(1 << 15), (1 << 15) - 1)   # re-quantize to int16
        ref = _pool_oracle(np.maximum(y, 0))
        if not elementwise_pum:
            return ref
        out = relu_maxpool2x2_pum(dev, y, 16)
        verify(np.array_equal(out, ref), "lenet conv-block relu+pool mismatch")
        return out

    x = conv_block(x, conv_channels[0], 5, pad=2)     # 6×14×14
    x = conv_block(x, conv_channels[1], 5, pad=0)     # 16×5×5
    feat = x.reshape(-1)

    for i, width in enumerate(fc_dims):
        w = rng.integers(-8, 8, size=(width, feat.shape[0])).astype(np.int64)
        total_macs += width * feat.shape[0]
        LayerCost("fc", width * feat.shape[0], width).account_matmul(dev, 8)
        feat = dense_int(feat, w)
        feat = np.clip(feat >> 4, -(1 << 15), (1 << 15) - 1)
        if i != len(fc_dims) - 1:
            ref = np.maximum(feat, 0)
            if elementwise_pum:
                feat = relu_pum(dev, feat, 16)
                verify(np.array_equal(feat, ref), "lenet fc relu mismatch")
            else:
                feat = ref

    return {"arch": "lenet5", "macs": total_macs, "pred": int(np.argmax(feat)),
            "backend": dev.backend, "verified": True, "output": feat,
            **dev.totals()}
