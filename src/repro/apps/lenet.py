"""LeNet-5 quantized inference on SIMDRAM (paper §5 app kernel)."""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice
from .nn_layers import LayerCost, conv2d_int, dense_int, maxpool2x2_pum, relu_pum


def run(device: SimdramDevice | None = None, seed: int = 0,
        elementwise_pum: bool = True) -> Dict:
    dev = device or SimdramDevice(backend="bitplane")
    rng = np.random.default_rng(seed)

    x = rng.integers(0, 64, size=(1, 28, 28)).astype(np.int64)
    total_macs = 0

    def conv_block(x, c_out, k, pad):
        nonlocal total_macs
        c_in = x.shape[0]
        w = rng.integers(-8, 8, size=(c_out, c_in, k, k)).astype(np.int64)
        y = conv2d_int(x, w, pad=pad)
        macs = int(np.prod(y.shape)) * c_in * k * k
        total_macs += macs
        LayerCost("conv", macs, int(np.prod(y.shape))).account_matmul(dev, 8)
        y = np.clip(y >> 4, -(1 << 15), (1 << 15) - 1)
        ref = np.maximum(y, 0)
        y = relu_pum(dev, y, 16) if elementwise_pum else ref
        assert np.array_equal(y, ref)
        return maxpool2x2_pum(dev, y, 16) if elementwise_pum else \
            y.reshape(y.shape[0], y.shape[1] // 2, 2, y.shape[2] // 2, 2).max(axis=(2, 4))

    x = conv_block(x, 6, 5, pad=2)     # 6×14×14
    x = conv_block(x, 16, 5, pad=0)    # 16×5×5
    feat = x.reshape(-1)

    for width in (120, 84, 10):
        w = rng.integers(-8, 8, size=(width, feat.shape[0])).astype(np.int64)
        total_macs += width * feat.shape[0]
        LayerCost("fc", width * feat.shape[0], width).account_matmul(dev, 8)
        feat = dense_int(feat, w)
        feat = np.clip(feat >> 4, -(1 << 15), (1 << 15) - 1)
        if width != 10:
            ref = np.maximum(feat, 0)
            feat = relu_pum(dev, feat, 16) if elementwise_pum else ref
            assert np.array_equal(feat, ref)

    return {"arch": "lenet5", "macs": total_macs, "pred": int(np.argmax(feat)),
            **dev.totals()}
