"""Image brightness adjustment on SIMDRAM (paper §5 app kernel).

out = clamp(pixel + delta, 0, 255) per channel — a bulk add with
saturation (Gonzalez & Woods' brightness operator).  Each pixel shard is
one five-instruction ``Ref`` chain (add → underflow test → floor select
→ overflow test → ceiling select) drained through
:meth:`SimdramDevice.dispatch`, so the sum and its predicate bits
forward vertically between instructions on the fused backends.

10-bit two's-complement arithmetic covers any ``delta`` in
``[-255, 255]``: sums lie in ``[-255, 510]``, and a negative sum is
exactly one whose unsigned 10-bit encoding is ``>= 512`` (bit 9 set) —
so the clamp needs only unsigned relationals.  Deltas outside that
range raise ``ValueError`` (the seed silently mis-wrapped them).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice

from .runtime import (QueueBuilder, gather, n_parallel_units,
                      resolve_device, shard_slices, verify)


def run(
    h: int = 128,
    w: int = 128,
    delta: int = 40,
    device: SimdramDevice | None = None,
    backend: str = "bitplane",
    seed: int = 0,
) -> Dict:
    if not -255 <= delta <= 255:
        raise ValueError(
            f"delta must be in [-255, 255] for 10-bit saturating add, "
            f"got {delta}")
    dev = resolve_device(device, backend)
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 256, size=(3, h, w)).astype(np.int64)
    flat = img.reshape(-1)

    qb = QueueBuilder()
    shards = []
    for sl in shard_slices(flat.size, n_parallel_units(dev)):
        px = flat[sl]
        zeros = np.zeros(px.shape, np.int64)
        r_s = qb.emit("addition", px, np.full(px.shape, delta % 1024, np.int64),
                      n_bits=10)
        r_under = qb.emit("greater_equal", r_s,
                          np.full(px.shape, 512, np.int64), n_bits=10)
        r_floor = qb.emit("if_else", r_under, zeros, r_s, n_bits=10)
        r_over = qb.emit("greater", r_floor,
                         np.full(px.shape, 255, np.int64), n_bits=10)
        r_out = qb.emit("if_else", r_over,
                        np.full(px.shape, 255, np.int64), r_floor, n_bits=10)
        shards.append((sl, r_out))

    results = dev.dispatch(qb.queue)
    clipped = gather(results, shards, flat.size)

    want = np.clip(img + delta, 0, 255).reshape(-1)
    verify(np.array_equal(clipped, want), "brightness mismatch",
           got=clipped[:8], want=want[:8])

    return {"arch": "brightness", "pixels": int(flat.size),
            "backend": dev.backend, "verified": True, "output": clipped,
            **dev.totals()}
