"""Image brightness adjustment on SIMDRAM (paper §5 app kernel).

out = clamp(pixel + delta, 0, 255) per channel — a bulk add with
saturation, i.e. addition + relational + predication bbops across every
pixel in parallel (Gonzalez & Woods' brightness operator).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice


def run(
    h: int = 128,
    w: int = 128,
    delta: int = 40,
    device: SimdramDevice | None = None,
    seed: int = 0,
) -> Dict:
    dev = device or SimdramDevice(backend="bitplane")
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 256, size=(3, h, w)).astype(np.int64)
    flat = img.reshape(-1)

    # 10-bit two's-complement arithmetic covers delta in [-255, 255]:
    # results lie in [-255, 510]; negatives have bit 9 set (unsigned >= 512)
    s = np.asarray(dev.bbop("addition", flat,
                            np.full_like(flat, delta % 1024), n_bits=10))
    under = np.asarray(dev.bbop("greater_equal", s,
                                np.full_like(s, 512), n_bits=10))
    s = np.asarray(dev.bbop("if_else", under.astype(np.int64),
                            np.zeros_like(s), s, n_bits=10))
    over = np.asarray(dev.bbop("greater", s, np.full_like(s, 255), n_bits=10))
    clipped = np.asarray(dev.bbop(
        "if_else", over.astype(np.int64), np.full_like(s, 255), s, n_bits=10))

    want = np.clip(img + delta, 0, 255).reshape(-1)
    assert np.array_equal(clipped, want), "brightness mismatch"

    return {"arch": "brightness", "pixels": int(flat.size), **dev.totals()}
