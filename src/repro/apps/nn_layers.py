"""Quantized NN building blocks over SIMDRAM bbops.

Convolutions/matmuls use the bit-serial formulation (kernel or analytic
accounting), elementwise stages (ReLU, residual adds, pooling compare
trees) run as real bbops on the selected backend.  Mirrors the paper's NN
kernels: int8 weights/activations, per-tensor power-of-two scales.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.isa import SimdramDevice, compile_op
from repro.core.timing import uprogram_latency_s
from repro.core.energy import uprogram_energy_nj


def quantize(x: np.ndarray, bits: int = 8, signed: bool = True) -> Tuple[np.ndarray, float]:
    """Symmetric power-of-two quantization."""
    amax = np.abs(x).max() or 1.0
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    scale = 2.0 ** np.floor(np.log2(qmax / amax)) if amax > 0 else 1.0
    q = np.clip(np.round(x * scale), -qmax - 1 if signed else 0, qmax)
    return q.astype(np.int32), float(scale)


def im2col(x: np.ndarray, kh: int, kw: int, stride: int = 1, pad: int = 0) -> Tuple[np.ndarray, int, int]:
    """(C, H, W) -> (out_h*out_w, C*kh*kw) patch matrix."""
    c, h, w = x.shape
    if pad:
        x = np.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (x.shape[1] - kh) // stride + 1
    ow = (x.shape[2] - kw) // stride + 1
    cols = np.zeros((oh * ow, c * kh * kw), dtype=x.dtype)
    idx = 0
    for i in range(oh):
        for j in range(ow):
            patch = x[:, i * stride: i * stride + kh, j * stride: j * stride + kw]
            cols[idx] = patch.reshape(-1)
            idx += 1
    return cols, oh, ow


@dataclass
class LayerCost:
    """Bit-serial command accounting for one offloaded layer."""
    name: str
    macs: int            # multiply-accumulates
    elements: int        # elementwise op lanes

    def account_matmul(self, dev: SimdramDevice, n_bits: int = 8) -> None:
        """Charge the device for a bit-serial MAC workload: each MAC is one
        n-bit multiplication + one 2n-bit addition μProgram lane."""
        _, up_mul = compile_op("multiplication", n_bits, dev.style)
        _, up_add = compile_op("addition", 2 * n_bits, dev.style)
        for up in (up_mul, up_add):
            dev._account(up.op_name, up.n_bits, up, self.macs)


def conv2d_int(
    x: np.ndarray, w: np.ndarray, stride: int = 1, pad: int = 0
) -> np.ndarray:
    """Integer conv via im2col + int matmul.  x: (C,H,W), w: (O,C,kh,kw)."""
    o, c, kh, kw = w.shape
    cols, oh, ow = im2col(x, kh, kw, stride, pad)
    wmat = w.reshape(o, -1).astype(np.int64)
    out = cols.astype(np.int64) @ wmat.T         # (oh*ow, O)
    return out.T.reshape(o, oh, ow)


def relu_pum(dev: SimdramDevice, x: np.ndarray, n_bits: int = 16) -> np.ndarray:
    """ReLU executed as a real SIMDRAM bbop (clips to n_bits two's compl.)."""
    flat = x.reshape(-1)
    lim = 1 << (n_bits - 1)
    clipped = np.clip(flat, -lim, lim - 1)
    out = np.asarray(
        dev.bbop("relu", clipped.astype(np.int64) & ((1 << n_bits) - 1),
                 n_bits=n_bits, signed_out=True)
    )
    return out.reshape(x.shape).astype(np.int64)


def maxpool2x2_pum(dev: SimdramDevice, x: np.ndarray, n_bits: int = 16) -> np.ndarray:
    """2×2 max-pool as a tree of SIMDRAM `max` bbops (signed)."""
    c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, : h2 * 2, : w2 * 2]
    a = x[:, 0::2, 0::2].reshape(-1)
    b = x[:, 0::2, 1::2].reshape(-1)
    cc = x[:, 1::2, 0::2].reshape(-1)
    d = x[:, 1::2, 1::2].reshape(-1)
    mask = (1 << n_bits) - 1

    def mx(u, v):
        # signed max via flipped-msb unsigned max (ops_library signed=True)
        dev_out = dev.bbop("if_else",
                           np.asarray(dev.bbop("greater",
                                               _bias(u, n_bits), _bias(v, n_bits),
                                               n_bits=n_bits)).astype(np.int64),
                           u.astype(np.int64) & mask, v.astype(np.int64) & mask,
                           n_bits=n_bits, signed_out=True)
        return np.asarray(dev_out).astype(np.int64)

    m1 = mx(a, b)
    m2 = mx(cc, d)
    m = mx(m1, m2)
    return m.reshape(c, h2, w2)


def _bias(x: np.ndarray, n_bits: int) -> np.ndarray:
    """Signed -> order-preserving unsigned (flip sign bit)."""
    return (x.astype(np.int64) + (1 << (n_bits - 1))) & ((1 << n_bits) - 1)


def dense_int(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    return x.astype(np.int64) @ w.astype(np.int64).T
