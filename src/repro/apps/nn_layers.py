"""Quantized NN building blocks over SIMDRAM bbops (paper §5 app kernel).

Convolutions/matmuls use the bit-serial formulation (kernel or analytic
accounting), elementwise stages (ReLU, pooling compare trees) run as
real bbops: each builds a ``Ref``-chained :class:`BbopInstr` queue per
lane shard and drains it through :meth:`SimdramDevice.dispatch`, so the
same code runs on every rung of the backend ladder.  Mirrors the
paper's NN kernels: int8 weights/activations, per-tensor power-of-two
scales.  :func:`run` drives a small conv → ReLU+pool → dense → ReLU
network end-to-end and verifies it against a numpy oracle.

Width plumbing: ReLU inputs must already fit ``n_bits``-bit
two's-complement — out-of-range activations raise ``ValueError``
instead of being silently clipped (the seed-era bug: a clip here
corrupts the network's numerics without failing verification of the
clipped tensor).  Signed max-pooling lowers onto the UNSIGNED ``max``
primitive via the order-preserving bias ``x + 2**(n_bits-1)``, un-biased
in-queue by a final signed subtraction.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.core.isa import SimdramDevice, compile_op

from .runtime import (QueueBuilder, gather, n_parallel_units,
                      resolve_device, shard_slices, verify)


def quantize(x: np.ndarray, bits: int = 8, signed: bool = True) -> Tuple[np.ndarray, float]:
    """Symmetric power-of-two quantization."""
    amax = np.abs(x).max() or 1.0
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    scale = 2.0 ** np.floor(np.log2(qmax / amax)) if amax > 0 else 1.0
    q = np.clip(np.round(x * scale), -qmax - 1 if signed else 0, qmax)
    return q.astype(np.int32), float(scale)


def im2col(x: np.ndarray, kh: int, kw: int, stride: int = 1, pad: int = 0) -> Tuple[np.ndarray, int, int]:
    """(C, H, W) -> (out_h*out_w, C*kh*kw) patch matrix."""
    c, h, w = x.shape
    if pad:
        x = np.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (x.shape[1] - kh) // stride + 1
    ow = (x.shape[2] - kw) // stride + 1
    cols = np.zeros((oh * ow, c * kh * kw), dtype=x.dtype)
    idx = 0
    for i in range(oh):
        for j in range(ow):
            patch = x[:, i * stride: i * stride + kh, j * stride: j * stride + kw]
            cols[idx] = patch.reshape(-1)
            idx += 1
    return cols, oh, ow


@dataclass
class LayerCost:
    """Bit-serial command accounting for one offloaded layer."""
    name: str
    macs: int            # multiply-accumulates
    elements: int        # elementwise op lanes

    def account_matmul(self, dev: SimdramDevice, n_bits: int = 8) -> None:
        """Charge the device for a bit-serial MAC workload: each MAC is one
        n-bit multiplication + one 2n-bit addition μProgram lane."""
        _, up_mul = compile_op("multiplication", n_bits, dev.style)
        _, up_add = compile_op("addition", 2 * n_bits, dev.style)
        for up in (up_mul, up_add):
            dev._account(up.op_name, up.n_bits, up, self.macs)


def conv2d_int(
    x: np.ndarray, w: np.ndarray, stride: int = 1, pad: int = 0
) -> np.ndarray:
    """Integer conv via im2col + int matmul.  x: (C,H,W), w: (O,C,kh,kw)."""
    o, c, kh, kw = w.shape
    cols, oh, ow = im2col(x, kh, kw, stride, pad)
    wmat = w.reshape(o, -1).astype(np.int64)
    out = cols.astype(np.int64) @ wmat.T         # (oh*ow, O)
    return out.T.reshape(o, oh, ow)


def dense_int(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    return x.astype(np.int64) @ w.astype(np.int64).T


def _bias(x: np.ndarray, n_bits: int) -> np.ndarray:
    """Signed -> order-preserving unsigned (flip sign bit)."""
    return (x.astype(np.int64) + (1 << (n_bits - 1))) & ((1 << n_bits) - 1)


def _check_range(flat: np.ndarray, n_bits: int, who: str) -> None:
    lim = 1 << (n_bits - 1)
    lo, hi = int(flat.min(initial=0)), int(flat.max(initial=0))
    if lo < -lim or hi >= lim:
        raise ValueError(
            f"{who}: activations [{lo}, {hi}] exceed {n_bits}-bit "
            f"two's-complement range [{-lim}, {lim - 1}]; widen n_bits "
            f"instead of silently clipping")


def relu_pum(dev: SimdramDevice, x: np.ndarray, n_bits: int = 16) -> np.ndarray:
    """ReLU as a dispatched queue of SIMDRAM ``relu`` bbops."""
    flat = x.reshape(-1).astype(np.int64)
    _check_range(flat, n_bits, "relu_pum")
    mask = (1 << n_bits) - 1

    qb = QueueBuilder()
    shards = []
    for sl in shard_slices(flat.size, n_parallel_units(dev)):
        shards.append((sl, qb.emit("relu", flat[sl] & mask, n_bits=n_bits)))
    out = gather(dev.dispatch(qb.queue), shards, flat.size)
    return out.reshape(x.shape)


def _pool_phases(x: np.ndarray):
    """(C,H,W) -> the four 2×2-phase planes, flattened, + pooled shape."""
    c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, : h2 * 2, : w2 * 2]
    phases = [x[:, 0::2, 0::2], x[:, 0::2, 1::2],
              x[:, 1::2, 0::2], x[:, 1::2, 1::2]]
    return [p.reshape(-1).astype(np.int64) for p in phases], (c, h2, w2)


def maxpool2x2_pum(dev: SimdramDevice, x: np.ndarray, n_bits: int = 16) -> np.ndarray:
    """Signed 2×2 max-pool as one dispatched queue per shard: unsigned
    ``max`` tree over sign-bit-biased operands, un-biased by an in-queue
    signed subtraction."""
    _check_range(x.reshape(-1), n_bits, "maxpool2x2_pum")
    (a, b, cc, d), (c, h2, w2) = _pool_phases(x)
    n = a.size
    bias = 1 << (n_bits - 1)

    qb = QueueBuilder()
    shards = []
    for sl in shard_slices(n, n_parallel_units(dev)):
        m1 = qb.emit("max", _bias(a[sl], n_bits), _bias(b[sl], n_bits),
                     n_bits=n_bits)
        m2 = qb.emit("max", _bias(cc[sl], n_bits), _bias(d[sl], n_bits),
                     n_bits=n_bits)
        m = qb.emit("max", m1, m2, n_bits=n_bits)
        r = qb.emit("subtraction", m, np.full(a[sl].shape, bias, np.int64),
                    n_bits=n_bits, signed_out=True)
        shards.append((sl, r))
    out = gather(dev.dispatch(qb.queue), shards, n)
    return out.reshape(c, h2, w2)


def relu_maxpool2x2_pum(
    dev: SimdramDevice, x: np.ndarray, n_bits: int = 16
) -> np.ndarray:
    """Fused ReLU → 2×2 max-pool as ONE queue: four ``relu`` bbops (one
    per pool phase) feed an unsigned ``max`` tree directly — ReLU output
    is non-negative, so no sign-bit bias is needed and the whole fusion
    is a seven-instruction ``Ref`` chain per shard."""
    _check_range(x.reshape(-1), n_bits, "relu_maxpool2x2_pum")
    phases, (c, h2, w2) = _pool_phases(x)
    n = phases[0].size
    mask = (1 << n_bits) - 1

    qb = QueueBuilder()
    shards = []
    for sl in shard_slices(n, n_parallel_units(dev)):
        rs = [qb.emit("relu", p[sl] & mask, n_bits=n_bits) for p in phases]
        m1 = qb.emit("max", rs[0], rs[1], n_bits=n_bits)
        m2 = qb.emit("max", rs[2], rs[3], n_bits=n_bits)
        shards.append((sl, qb.emit("max", m1, m2, n_bits=n_bits)))
    out = gather(dev.dispatch(qb.queue), shards, n)
    return out.reshape(c, h2, w2)


def _pool_oracle(x: np.ndarray) -> np.ndarray:
    c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, : h2 * 2, : w2 * 2]
    return np.maximum.reduce([x[:, 0::2, 0::2], x[:, 0::2, 1::2],
                              x[:, 1::2, 0::2], x[:, 1::2, 1::2]])


def run(
    in_ch: int = 2,
    img_hw: int = 8,
    out_ch: int = 3,
    fc_out: int = 5,
    n_bits: int = 16,
    device: SimdramDevice | None = None,
    backend: str = "bitplane",
    seed: int = 0,
) -> Dict:
    """Small conv → fused ReLU+pool → dense → ReLU network, every
    elementwise stage a dispatched bbop queue, verified stage-by-stage
    against numpy."""
    dev = resolve_device(device, backend)
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 8, size=(in_ch, img_hw, img_hw)).astype(np.int64)
    wc = rng.integers(-4, 4, size=(out_ch, in_ch, 3, 3)).astype(np.int64)

    conv = conv2d_int(x, wc, pad=1)
    macs = conv.size * in_ch * 9
    LayerCost("conv", macs=macs, elements=conv.size).account_matmul(dev)

    pooled = relu_maxpool2x2_pum(dev, conv, n_bits=n_bits)
    want_pool = _pool_oracle(np.maximum(conv, 0))
    verify(np.array_equal(pooled, want_pool), "fused relu+pool mismatch",
           got=pooled.reshape(-1)[:8], want=want_pool.reshape(-1)[:8])

    wf = rng.integers(-4, 4, size=(fc_out, pooled.size)).astype(np.int64)
    fc = dense_int(pooled.reshape(1, -1), wf)
    macs_fc = fc.size * pooled.size
    LayerCost("fc", macs=macs_fc, elements=fc.size).account_matmul(dev)

    out = relu_pum(dev, fc, n_bits=n_bits)
    want_out = np.maximum(fc, 0)
    verify(np.array_equal(out, want_out), "final relu mismatch",
           got=out.reshape(-1), want=want_out.reshape(-1))

    return {"arch": "nn_layers", "macs": macs + macs_fc,
            "backend": dev.backend, "verified": True,
            "output": out.reshape(-1), **dev.totals()}
