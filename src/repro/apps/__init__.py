"""The paper's seven application kernels (§5), on the SIMDRAM substrate.

  vgg.py         VGG-13 / VGG-16 quantized inference
  lenet.py       LeNet-5 quantized inference
  knn.py         k-nearest-neighbours (L1 distance + host top-k)
  tpch.py        TPC-H-style predicate scan + aggregate
  bitweaving.py  BitWeaving column scans
  brightness.py  image brightness adjustment (add + clamp predication)
  nn_layers.py   shared quantized-NN blocks + a small end-to-end net

Each kernel builds ``Ref``-chained :class:`~repro.core.bank.BbopInstr`
queues (one independent chain per lane shard — see
:mod:`repro.apps.runtime`) and drains them through
:meth:`~repro.core.isa.SimdramDevice.dispatch`, so the SAME app code
runs on every rung of the backend ladder: ``bitplane`` → ``bank`` →
``chip`` → ``channel``.  Host code remains only where the paper also
keeps the CPU involved (top-k, sums, matmul accounting).  Every kernel
verifies against a numpy oracle with a raising check and reports
``verified: True`` plus the per-device command statistics that feed
``benchmarks/paper_tables.py::table_apps``.
"""
