"""The paper's seven application kernels (§5), on the SIMDRAM substrate.

  vgg.py         VGG-13 / VGG-16 quantized inference
  lenet.py       LeNet-5 quantized inference
  knn.py         k-nearest-neighbours (L1 distance + min tree)
  tpch.py        TPC-H-style predicate scan + aggregate
  bitweaving.py  BitWeaving column scans
  brightness.py  image brightness adjustment (add + clamp predication)

Each kernel runs end-to-end with real data through SIMDRAM bbops (host
code only where the paper also keeps the CPU involved), verifies against
a numpy oracle, and reports the per-device command statistics that feed
benchmarks/apps.py.
"""
