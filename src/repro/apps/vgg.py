"""VGG-13 / VGG-16 quantized inference on the SIMDRAM substrate (paper §5).

Convolution MACs are charged to the device as bit-serial mul+add
μPrograms (the paper's accounting); ReLU and max-pool stages execute as
*real* dispatched bbop queues on the selected backend.  The plan walker
looks one item ahead: a conv whose ReLU is immediately followed by
``'M'`` fuses both into one
:func:`~repro.apps.nn_layers.relu_maxpool2x2_pum` ``Ref`` chain;
stand-alone stages use :func:`~repro.apps.nn_layers.relu_pum` /
:func:`~repro.apps.nn_layers.maxpool2x2_pum`.  Synthetic int8 weights;
every stage verifies against an integer numpy oracle with a raising
check.

``run(arch="vgg13"|"vgg16", n_layers=k, ...)`` truncates the plan to
its first ``k`` items (for fast cross-backend gating) and returns
command/latency/energy totals.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice

from .nn_layers import (LayerCost, _pool_oracle, conv2d_int, maxpool2x2_pum,
                        relu_maxpool2x2_pum, relu_pum)
from .runtime import resolve_device, verify

# (conv channel plan per block, 'M' = 2x2 maxpool) — standard VGG configs
VGG_PLANS = {
    "vgg13": [64, 64, "M", 128, 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"],
    "vgg16": [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"],
}


def run(
    arch: str = "vgg13",
    img_hw: int = 32,
    n_classes: int = 10,
    n_layers: int | None = None,
    device: SimdramDevice | None = None,
    backend: str = "bitplane",
    seed: int = 0,
    elementwise_pum: bool = True,
) -> Dict:
    dev = resolve_device(device, backend)
    rng = np.random.default_rng(seed)
    plan = VGG_PLANS[arch]
    if n_layers is not None:
        plan = plan[:n_layers]

    x = rng.integers(-64, 64, size=(3, img_hw, img_hw)).astype(np.int64)
    c_in = 3
    total_macs = 0
    li = 0
    while li < len(plan):
        item = plan[li]
        if item == "M":
            ref = _pool_oracle(x)
            if elementwise_pum:
                x = maxpool2x2_pum(dev, x, n_bits=16)
                verify(np.array_equal(x, ref), f"{arch} maxpool L{li}")
            else:
                x = ref
            li += 1
            continue
        c_out = int(item)
        w = rng.integers(-8, 8, size=(c_out, c_in, 3, 3)).astype(np.int64)
        y = conv2d_int(x, w, stride=1, pad=1)
        macs = int(np.prod(y.shape)) * c_in * 9
        total_macs += macs
        LayerCost(f"conv{li}", macs, int(np.prod(y.shape))).account_matmul(dev, n_bits=8)
        # re-quantize activations to int16 range then ReLU (+pool) in PuM
        y = np.clip(y >> 6, -(1 << 15), (1 << 15) - 1)
        fuse_pool = li + 1 < len(plan) and plan[li + 1] == "M"
        if fuse_pool:
            ref = _pool_oracle(np.maximum(y, 0))
            if elementwise_pum:
                y = relu_maxpool2x2_pum(dev, y, n_bits=16)
                verify(np.array_equal(y, ref), f"{arch} relu+pool L{li}")
            else:
                y = ref
            li += 2
        else:
            ref = np.maximum(y, 0)
            if elementwise_pum:
                y = relu_pum(dev, y, n_bits=16)
                verify(np.array_equal(y, ref), f"{arch} relu L{li}")
            else:
                y = ref
            li += 1
        x = y
        c_in = c_out

    # classifier head (host-side, like the paper's CPU fallback)
    feat = x.reshape(-1)
    wfc = rng.integers(-8, 8, size=(n_classes, feat.shape[0])).astype(np.int64)
    logits = wfc @ feat
    return {
        "arch": arch,
        "macs": total_macs,
        "pred": int(np.argmax(logits)),
        "backend": dev.backend,
        "verified": True,
        "output": logits,
        **dev.totals(),
    }
