"""VGG-13 / VGG-16 quantized inference on the SIMDRAM substrate (paper §5).

Convolution MACs are charged to the device as bit-serial mul+add
μPrograms (the paper's accounting); ReLU and max-pool stages execute as
*real* bbops.  Synthetic int8 weights; correctness is asserted against an
integer numpy oracle layer-by-layer.

`run(arch="vgg13"|"vgg16", ...)` returns command/latency/energy totals.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.core.isa import SimdramDevice
from .nn_layers import LayerCost, conv2d_int, maxpool2x2_pum, relu_pum

# (conv channel plan per block, 'M' = 2x2 maxpool) — standard VGG configs
VGG_PLANS = {
    "vgg13": [64, 64, "M", 128, 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"],
    "vgg16": [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"],
}


def run(
    arch: str = "vgg13",
    img_hw: int = 32,
    n_classes: int = 10,
    device: SimdramDevice | None = None,
    seed: int = 0,
    elementwise_pum: bool = True,
) -> Dict:
    dev = device or SimdramDevice(backend="bitplane")
    rng = np.random.default_rng(seed)
    plan = VGG_PLANS[arch]

    x = rng.integers(-64, 64, size=(3, img_hw, img_hw)).astype(np.int64)
    c_in = 3
    total_macs = 0
    for li, item in enumerate(plan):
        if item == "M":
            ref = x.reshape(x.shape[0], x.shape[1] // 2, 2, x.shape[2] // 2, 2).max(axis=(2, 4))
            if elementwise_pum:
                x = maxpool2x2_pum(dev, x, n_bits=16)
                assert np.array_equal(x, ref), f"{arch} maxpool L{li}"
            else:
                x = ref
            continue
        c_out = int(item)
        w = rng.integers(-8, 8, size=(c_out, c_in, 3, 3)).astype(np.int64)
        y = conv2d_int(x, w, stride=1, pad=1)
        macs = int(np.prod(y.shape)) * c_in * 9
        total_macs += macs
        LayerCost(f"conv{li}", macs, int(np.prod(y.shape))).account_matmul(dev, n_bits=8)
        # re-quantize activations to int16 range then ReLU in PuM
        y = np.clip(y >> 6, -(1 << 15), (1 << 15) - 1)
        ref = np.maximum(y, 0)
        if elementwise_pum:
            y = relu_pum(dev, y, n_bits=16)
            assert np.array_equal(y, ref), f"{arch} relu L{li}"
        else:
            y = ref
        x = y
        c_in = c_out

    # classifier head (host-side, like the paper's CPU fallback)
    feat = x.reshape(-1)
    wfc = rng.integers(-8, 8, size=(n_classes, feat.shape[0])).astype(np.int64)
    logits = wfc @ feat
    t = dev.totals()
    return {
        "arch": arch,
        "macs": total_macs,
        "pred": int(np.argmax(logits)),
        **t,
    }
