"""BitWeaving column scans on SIMDRAM (paper §5 app kernel).

BitWeaving (Li & Patel, SIGMOD'13) evaluates predicates over bit-packed
columns; its vertical (BitWeaving/V) layout is precisely SIMDRAM's
vertical layout, so a predicate scan is a single relational bbop over
all rows.  The three device-side scans (=, >, >=) over every row shard
go into ONE dispatch queue; the complements (!=, <, <=) derive
host-side as ``1 - x`` on the returned bit-vectors, exactly as a scan
engine would negate a result bit-vector.  All six selectivities verify
against numpy.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice

from .runtime import (QueueBuilder, gather, n_parallel_units,
                      resolve_device, shard_slices, verify)


def run(
    n_rows: int = 65536,
    n_bits: int = 12,
    device: SimdramDevice | None = None,
    backend: str = "bitplane",
    seed: int = 0,
) -> Dict:
    dev = resolve_device(device, backend)
    rng = np.random.default_rng(seed)
    col = rng.integers(0, 1 << n_bits, size=n_rows).astype(np.int64)
    c = int(rng.integers(0, 1 << n_bits))

    qb = QueueBuilder()
    shards = []
    for sl in shard_slices(n_rows, n_parallel_units(dev)):
        x = col[sl]
        cc = np.full(x.shape, c, np.int64)
        r_eq = qb.emit("equal", x, cc, n_bits=n_bits)
        r_gt = qb.emit("greater", x, cc, n_bits=n_bits)
        r_ge = qb.emit("greater_equal", x, cc, n_bits=n_bits)
        shards.append((sl, (r_eq, r_gt, r_ge)))

    results = dev.dispatch(qb.queue)
    eq = gather(results, [(sl, r) for sl, (r, _, _) in shards], n_rows)
    gt = gather(results, [(sl, r) for sl, (_, r, _) in shards], n_rows)
    ge = gather(results, [(sl, r) for sl, (_, _, r) in shards], n_rows)

    preds = {
        "eq": eq, "ne": 1 - eq, "gt": gt, "ge": ge, "lt": 1 - ge, "le": 1 - gt,
    }
    oracle = {
        "eq": col == c, "ne": col != c, "gt": col > c,
        "ge": col >= c, "lt": col < c, "le": col <= c,
    }
    for k in preds:
        verify(np.array_equal(preds[k].astype(bool), oracle[k]),
               f"bitweaving {k} scan mismatch")

    return {
        "arch": "bitweaving", "rows": n_rows, "n_bits": n_bits,
        "sel_eq": int(eq.sum()), "sel_gt": int(gt.sum()),
        "backend": dev.backend, "verified": True,
        "output": np.concatenate([eq, gt, ge]), **dev.totals(),
    }
