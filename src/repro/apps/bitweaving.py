"""BitWeaving column scans on SIMDRAM (paper §5 app kernel).

BitWeaving (Li & Patel, SIGMOD'13) evaluates predicates over bit-packed
columns; its vertical (BitWeaving/V) layout is precisely SIMDRAM's
vertical layout, so a predicate scan is a single relational bbop over all
rows.  We scan a column with <, <=, =, !=, >, >= predicates against a
constant and verify selectivities against numpy.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice


def run(
    n_rows: int = 65536,
    n_bits: int = 12,
    device: SimdramDevice | None = None,
    seed: int = 0,
) -> Dict:
    dev = device or SimdramDevice(backend="bitplane")
    rng = np.random.default_rng(seed)
    col = rng.integers(0, 1 << n_bits, size=n_rows).astype(np.int64)
    c = int(rng.integers(0, 1 << n_bits))
    cc = np.full_like(col, c)

    eq = np.asarray(dev.bbop("equal", col, cc, n_bits=n_bits))
    gt = np.asarray(dev.bbop("greater", col, cc, n_bits=n_bits))
    ge = np.asarray(dev.bbop("greater_equal", col, cc, n_bits=n_bits))
    preds = {
        "eq": eq, "ne": 1 - eq, "gt": gt, "ge": ge, "lt": 1 - ge, "le": 1 - gt,
    }
    oracle = {
        "eq": col == c, "ne": col != c, "gt": col > c,
        "ge": col >= c, "lt": col < c, "le": col <= c,
    }
    for k in preds:
        assert np.array_equal(preds[k].astype(bool), oracle[k]), f"bitweaving {k}"

    return {
        "arch": "bitweaving", "rows": n_rows, "n_bits": n_bits,
        "sel_eq": int(eq.sum()), "sel_gt": int(gt.sum()), **dev.totals(),
    }
