"""TPC-H-style predicate scan + aggregation on SIMDRAM (paper §5).

Models the selection/aggregation core of TPC-H Q6:

  SELECT SUM(extendedprice * discount) FROM lineitem
  WHERE shipdate in range AND discount BETWEEN lo AND hi AND quantity < q

All predicates evaluate as SIMDRAM relational bbops over every row in
parallel; the conjunction is an and_red; the aggregation masks via
if_else then sums host-side (the paper aggregates partial sums on the
CPU too).  Verified against a numpy query oracle.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice


def run(
    n_rows: int = 8192,
    device: SimdramDevice | None = None,
    seed: int = 0,
) -> Dict:
    dev = device or SimdramDevice(backend="bitplane")
    rng = np.random.default_rng(seed)

    shipdate = rng.integers(0, 2556, size=n_rows).astype(np.int64)      # days
    quantity = rng.integers(1, 51, size=n_rows).astype(np.int64)
    discount = rng.integers(0, 11, size=n_rows).astype(np.int64)        # percent
    price = rng.integers(100, 10000, size=n_rows).astype(np.int64)

    d_lo, d_hi, q_lt = 4, 6, 24
    t_lo, t_hi = 365, 730

    def ge(x, c, bits):
        return np.asarray(dev.bbop("greater_equal", x, np.full_like(x, c), n_bits=bits))

    def lt(x, c, bits):
        return 1 - ge(x, c, bits)

    p1 = ge(shipdate, t_lo, 12) & lt(shipdate, t_hi, 12)
    p2 = ge(discount, d_lo, 4) & (1 - np.asarray(
        dev.bbop("greater", discount, np.full_like(discount, d_hi), n_bits=4)))
    p3 = lt(quantity, q_lt, 6)
    sel = np.asarray(dev.bbop(
        "and_red", p1.astype(np.int64), p2.astype(np.int64), p3.astype(np.int64),
        np.ones_like(p1, dtype=np.int64), n_bits=1))

    # revenue = price * discount on selected rows (PuM multiply + predication)
    prod = np.asarray(dev.bbop("multiplication", price, discount, n_bits=14))
    masked = np.asarray(dev.bbop("if_else", sel.astype(np.int64), prod,
                                 np.zeros_like(prod), n_bits=28))
    revenue = int(masked.sum())

    want_sel = ((shipdate >= t_lo) & (shipdate < t_hi)
                & (discount >= d_lo) & (discount <= d_hi) & (quantity < q_lt))
    want = int((price * discount)[want_sel].sum())
    assert revenue == want, (revenue, want)

    return {"arch": "tpch_q6", "rows": n_rows, "selected": int(sel.sum()),
            "revenue": revenue, **dev.totals()}
