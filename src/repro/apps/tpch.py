"""TPC-H-style predicate scan + aggregation on SIMDRAM (paper §5).

Models the selection/aggregation core of TPC-H Q6:

  SELECT SUM(extendedprice * discount) FROM lineitem
  WHERE shipdate in range AND discount BETWEEN lo AND hi AND quantity < q

The whole query body is one ``Ref`` chain per row shard — five
relational bbops, a two-level ``and_red`` conjunction, the PuM multiply
and the predicating ``if_else`` — drained through
:meth:`SimdramDevice.dispatch` so the predicate bit-vectors forward
vertically between instructions on the fused backends.  The paper's
``<``/``<=`` comparisons against constants lower onto the unsigned
``greater``/``greater_equal`` primitives with the constant as the LEFT
operand (``x < c  ≡  c > x``), keeping every predicate in-queue.  Only
the final SUM of masked revenues happens host-side (the paper
aggregates partial sums on the CPU too).  Verified against a numpy
query oracle.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice

from .runtime import (QueueBuilder, gather, n_parallel_units,
                      resolve_device, shard_slices, verify)


def run(
    n_rows: int = 8192,
    device: SimdramDevice | None = None,
    backend: str = "bitplane",
    seed: int = 0,
) -> Dict:
    dev = resolve_device(device, backend)
    rng = np.random.default_rng(seed)

    shipdate = rng.integers(0, 2556, size=n_rows).astype(np.int64)      # days
    quantity = rng.integers(1, 51, size=n_rows).astype(np.int64)
    discount = rng.integers(0, 11, size=n_rows).astype(np.int64)        # percent
    price = rng.integers(100, 10000, size=n_rows).astype(np.int64)

    d_lo, d_hi, q_lt = 4, 6, 24
    t_lo, t_hi = 365, 730

    qb = QueueBuilder()
    shards = []
    for sl in shard_slices(n_rows, n_parallel_units(dev)):
        sd, qt, dc, pr = shipdate[sl], quantity[sl], discount[sl], price[sl]

        def full(c, like):
            return np.full(like.shape, c, np.int64)

        r_tlo = qb.emit("greater_equal", sd, full(t_lo, sd), n_bits=12)
        r_thi = qb.emit("greater", full(t_hi, sd), sd, n_bits=12)       # sd < t_hi
        r_dlo = qb.emit("greater_equal", dc, full(d_lo, dc), n_bits=4)
        r_dhi = qb.emit("greater_equal", full(d_hi, dc), dc, n_bits=4)  # dc <= d_hi
        r_q = qb.emit("greater", full(q_lt, qt), qt, n_bits=6)          # qt < q_lt
        r_a = qb.emit("and_red", r_tlo, r_thi, r_dlo, r_dhi, n_bits=1)
        ones = np.ones(sd.shape, np.int64)
        r_sel = qb.emit("and_red", r_a, r_q, ones, ones, n_bits=1)
        r_mul = qb.emit("multiplication", pr, dc, n_bits=14)
        r_rev = qb.emit("if_else", r_sel, r_mul,
                        np.zeros(sd.shape, np.int64), n_bits=28)
        shards.append((sl, (r_sel, r_rev)))

    results = dev.dispatch(qb.queue)
    sel = gather(results, [(sl, rs) for sl, (rs, _) in shards], n_rows)
    masked = gather(results, [(sl, rr) for sl, (_, rr) in shards], n_rows)
    revenue = int(masked.sum())

    want_sel = ((shipdate >= t_lo) & (shipdate < t_hi)
                & (discount >= d_lo) & (discount <= d_hi) & (quantity < q_lt))
    want = int((price * discount)[want_sel].sum())
    verify(revenue == want, "TPC-H Q6 revenue mismatch",
           got=revenue, want=want)
    verify(np.array_equal(sel.astype(bool), want_sel),
           "TPC-H Q6 selection-vector mismatch")

    return {"arch": "tpch_q6", "rows": n_rows, "selected": int(sel.sum()),
            "revenue": revenue, "backend": dev.backend, "verified": True,
            "output": masked, **dev.totals()}
