"""Shared runtime for the seven application kernels (paper §5).

Every app builds a :class:`~repro.core.bank.BbopInstr` queue — one
producer→consumer ``Ref`` chain per lane shard — and drains it through
:meth:`repro.core.isa.SimdramDevice.dispatch`, so the SAME kernel code
exercises the whole backend ladder:

  "bitplane"   per-instruction sequential drain (seed-era fast path)
  "bank"       fused heterogeneous waves across the bank's subarrays
  "chip"       per-bank partitioned rounds, shard_map over "data"
  "channel"    per-chip super-rounds on a 2-D ("channel", "data") mesh,
               host↔chip transfers priced at cfg.channel_bw_gbs

Ref-connected chains are indivisible under the chip/channel LPT
partitioners (forwarded bit-planes never cross banks or chips), so an
app that wants tier parallelism must emit SEVERAL independent chains —
:func:`shard_slices` splits the lane space into one chain per compute
unit (:func:`n_parallel_units`).  Results stay bit-exact for any shard
count; sharding only changes the schedule.

Correctness reporting: apps verify against their numpy oracle with
:func:`verify` — a real raising check (``python -O`` strips bare
``assert`` statements, the seed-era bug) — and surface ``verified:
True`` in their result dict.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.bank import BbopInstr, Ref
from repro.core.isa import SimdramDevice

#: the backend ladder every app is bit-exactness-gated across
LADDER = ("bitplane", "bank", "chip", "channel")


class AppVerificationError(AssertionError):
    """An app kernel's SIMDRAM output diverged from its numpy oracle."""


def verify(ok: bool, message: str, got=None, want=None) -> None:
    """Raising correctness check (survives ``python -O``, unlike a bare
    ``assert``)."""
    if ok:
        return
    if got is not None or want is not None:
        message = f"{message} (got={got!r}, want={want!r})"
    raise AppVerificationError(message)


def resolve_device(device: Optional[SimdramDevice], backend: str,
                   cfg=None, style: str = "mig") -> SimdramDevice:
    """An explicit ``device`` wins; otherwise build one for ``backend``
    (the apps' backend parameter — no more hardcoded seed-era
    ``backend="bitplane"``)."""
    if device is not None:
        return device
    kw = dict(backend=backend, style=style)
    if cfg is not None:
        kw["cfg"] = cfg
    return SimdramDevice(**kw)


def n_parallel_units(dev: SimdramDevice) -> int:
    """How many independent Ref chains the device's backend can work on
    concurrently: chains are indivisible under the chip/channel
    partitioners, so this is the count of (chip ×) bank × subarray slots
    — 1 for the sequential single-subarray backends."""
    cfg = dev.cfg
    per_chip = cfg.n_banks * cfg.subarrays_per_bank
    return {"bank": per_chip, "chip": per_chip,
            "channel": cfg.n_chips * per_chip}.get(dev.backend, 1)


def shard_slices(n: int, units: int, min_lanes: int = 32) -> List[slice]:
    """Split ``n`` lanes into up to ``units`` contiguous shards of at
    least ``min_lanes`` each (tiny shards waste replay slots)."""
    if n <= 0:
        return []
    k = max(1, min(units, n // min_lanes or 1))
    per = -(-n // k)
    return [slice(s, min(s + per, n)) for s in range(0, n, per)]


class QueueBuilder:
    """Accumulates one dispatch queue; :meth:`emit` returns the ``Ref``
    that forwards the new instruction's first output vertically into a
    later instruction."""

    def __init__(self):
        self.queue: List[BbopInstr] = []

    def emit(self, op: str, *operands, n_bits: int,
             signed_out: bool = False, keep_vertical: bool = False) -> Ref:
        self.queue.append(
            BbopInstr(op, tuple(operands), int(n_bits),
                      signed_out=signed_out, keep_vertical=keep_vertical))
        return Ref(len(self.queue) - 1, 0)

    def __len__(self) -> int:
        return len(self.queue)


def take(results: Sequence, ref: Ref) -> np.ndarray:
    """Pull one dispatched result as a flat int64 array."""
    r = results[ref.producer]
    vals = r[ref.out] if isinstance(r, tuple) else r
    return np.asarray(vals).astype(np.int64)


def gather(results: Sequence, shards, n: int) -> np.ndarray:
    """Reassemble per-shard results: ``shards`` is [(slice, Ref), ...]
    covering ``[0, n)``."""
    out = np.zeros(n, np.int64)
    for sl, ref in shards:
        out[sl] = take(results, ref)
    return out


def engine_stats_object(dev: SimdramDevice):
    """The backend engine's live Stats object — ``None`` for the
    engine-less sequential backends.  Callers that want the registry
    form pass this to :func:`repro.core.telemetry.publish_stats`."""
    if dev.backend == "bank":
        return dev.bank().stats
    if dev.backend == "chip":
        return dev.chip().stats
    if dev.backend == "channel":
        return dev.channel().stats
    return None


def engine_stats(dev: SimdramDevice) -> Optional[Dict]:
    """The backend engine's own stats dict (wave fusion, rounds,
    transfers, measured wall) — ``None`` for the engine-less sequential
    backends, whose only model is the device-level :meth:`totals`."""
    stats = engine_stats_object(dev)
    return stats.as_dict() if stats is not None else None
