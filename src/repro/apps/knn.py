"""k-nearest-neighbours on SIMDRAM (paper §5 app kernel).

Distance computation is the bulk-parallel part: L1 distance between the
query and every reference point, built as one subtract→abs→accumulate
``Ref`` chain per feature (all N reference points as SIMD lanes) and
drained through :meth:`SimdramDevice.dispatch` — the chain's
intermediate bit-planes forward vertically on the fused backends.  Lanes
shard into one independent chain per compute unit so the chip/channel
partitioners can spread the work.  Top-k selection happens host-side on
the N distances (tiny), matching the paper's split.

Width/signedness plumbing (the seed-era audit): differences are
computed at ``n_bits + 1`` with ``signed_out=True`` — any pair drawn
from one ``2**n_bits``-wide window (unsigned ``[0, 2**n_bits)`` or
signed ``[-2**(n_bits-1), 2**(n_bits-1))``) differs by at most
``2**n_bits - 1``, which an (n+1)-bit two's-complement word represents
exactly, including both ``±2**(n_bits-1)`` edges.  ``abs`` then yields
a NON-negative (n+1)-bit value, so it is emitted unsigned
(``signed_out=False``): forwarding into the wider accumulator must
zero-extend, and the accumulator width ``n_bits +
ceil(log2(n_features)) + 1`` holds the worst-case sum exactly.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice

from .runtime import (QueueBuilder, gather, n_parallel_units,
                      resolve_device, shard_slices, verify)


def l1_distance(dev: SimdramDevice, refs: np.ndarray, query: np.ndarray,
                n_bits: int) -> np.ndarray:
    """L1 distances from ``query`` to every row of ``refs`` via one
    dispatched bbop queue.  All values must lie in one ``2**n_bits``-wide
    window (see module docstring) for the (n+1)-bit differences to be
    exact."""
    n_points, n_features = refs.shape
    diff_bits = n_bits + 1
    acc_bits = n_bits + max(int(np.ceil(np.log2(max(n_features, 1)))), 0) + 1
    dmask = (1 << diff_bits) - 1

    qb = QueueBuilder()
    shards = []
    for sl in shard_slices(n_points, n_parallel_units(dev)):
        acc = None
        for f in range(n_features):
            col = refs[sl, f].astype(np.int64) & dmask
            q = np.full(col.shape, int(query[f]) & dmask, np.int64)
            d = qb.emit("subtraction", col, q, n_bits=diff_bits,
                        signed_out=True)
            a = qb.emit("abs", d, n_bits=diff_bits)
            prev = acc if acc is not None else np.zeros(col.shape, np.int64)
            acc = qb.emit("addition", prev, a, n_bits=acc_bits)
        shards.append((sl, acc))
    results = dev.dispatch(qb.queue)
    return gather(results, shards, n_points)


def run(
    n_points: int = 4096,
    n_features: int = 16,
    k: int = 5,
    n_bits: int = 8,
    device: SimdramDevice | None = None,
    backend: str = "bitplane",
    signed: bool = False,
    seed: int = 0,
) -> Dict:
    dev = resolve_device(device, backend)
    rng = np.random.default_rng(seed)
    if signed:
        lo, hi = -(1 << (n_bits - 1)), 1 << (n_bits - 1)
    else:
        lo, hi = 0, 1 << n_bits
    refs = rng.integers(lo, hi, size=(n_points, n_features)).astype(np.int64)
    labels = rng.integers(0, 4, size=n_points)
    query = rng.integers(lo, hi, size=(n_features,)).astype(np.int64)

    dist = l1_distance(dev, refs, query, n_bits)

    want = np.abs(refs - query[None, :]).sum(axis=1)
    verify(np.array_equal(dist, want), "kNN L1 distance mismatch",
           got=dist[:8], want=want[:8])

    nearest = np.argsort(dist, kind="stable")[:k]
    pred = int(np.bincount(labels[nearest]).argmax())
    return {"arch": "knn", "n_points": n_points, "pred": pred,
            "backend": dev.backend, "verified": True, "output": dist,
            **dev.totals()}
