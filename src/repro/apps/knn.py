"""k-nearest-neighbours on SIMDRAM (paper §5 app kernel).

Distance computation is the bulk-parallel part: L1 distance between the
query and every reference point, computed feature-by-feature with
SIMDRAM subtraction + abs + addition bbops (each bbop processes all N
reference points as SIMD lanes).  Top-k selection happens host-side on
the N distances (tiny), matching the paper's split.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.isa import SimdramDevice


def run(
    n_points: int = 4096,
    n_features: int = 16,
    k: int = 5,
    n_bits: int = 8,
    device: SimdramDevice | None = None,
    seed: int = 0,
) -> Dict:
    dev = device or SimdramDevice(backend="bitplane")
    rng = np.random.default_rng(seed)
    refs = rng.integers(0, 1 << n_bits, size=(n_points, n_features)).astype(np.int64)
    labels = rng.integers(0, 4, size=n_points)
    query = rng.integers(0, 1 << n_bits, size=(n_features,)).astype(np.int64)

    acc_bits = n_bits + int(np.ceil(np.log2(n_features))) + 1
    dist = np.zeros(n_points, dtype=np.int64)
    for f in range(n_features):
        col = refs[:, f]
        q = np.full_like(col, query[f])
        diff = np.asarray(dev.bbop("subtraction", col, q, n_bits=n_bits + 1))
        ad = np.asarray(dev.bbop("abs", diff, n_bits=n_bits + 1, signed_out=True))
        dist = np.asarray(dev.bbop("addition", dist, ad.astype(np.int64),
                                   n_bits=acc_bits))

    want = np.abs(refs - query[None, :]).sum(axis=1)
    assert np.array_equal(dist, want), "kNN distance mismatch"

    nearest = np.argsort(dist)[:k]
    pred = int(np.bincount(labels[nearest]).argmax())
    return {"arch": "knn", "n_points": n_points, "pred": pred, **dev.totals()}
