"""``repro.serving`` — the multi-tenant request-stream tier.

The front door of the ladder: many concurrent client streams submit
single-bbop requests with deadlines and priorities; the front-end
applies admission control, coalesces compatible requests across tenants
into shared waves, drains them through one engine dispatch, and fans
results back out to per-request tickets — degrading gracefully (typed
rejections, host-oracle fallback behind a per-tenant circuit breaker)
instead of stalling or crashing under overload and injected faults.

    from repro.serving import ServingFrontend

    fe = ServingFrontend()                       # owns a SimdramChannel
    t = fe.submit("alice", "addition", (a, b), n_bits=8,
                  deadline_s=fe.now_s + 1e-3)
    fe.drain()                                   # or fe.start() a worker
    print(t.result())

Strictly free when unused: importing this package, and the ``cancel``/
re-entrancy hooks it added to the engines, change nothing about the
synchronous ``dispatch`` path (zero new XLA traces, bit-identical
results — CI-gated in ``benchmarks/serving_soak.py``).
"""

from .frontend import (  # noqa: F401
    AdmissionRejected,
    BreakerState,
    CircuitBreaker,
    DeadlineExceeded,
    FrontendStats,
    ServingFrontend,
    Ticket,
)

__all__ = [
    "AdmissionRejected",
    "BreakerState",
    "CircuitBreaker",
    "DeadlineExceeded",
    "FrontendStats",
    "ServingFrontend",
    "Ticket",
]
