"""Async multi-tenant serving front-end over the SIMDRAM ladder.

The engines (bank / chip / channel) execute ONE synchronous caller's
queue at a time — by design: the fused dispatchers keep double-buffered
pack state on the engine while a queue drains, and
:class:`~repro.core.isa.DispatchGuard` rejects concurrent entry.  This
module is the layer that turns that single-caller engine into a shared
service, the way the end-to-end SIMDRAM framework paper frames in-DRAM
compute as a transparently managed resource behind the memory
controller:

  - **Admission control** — a bounded queue; a full queue raises a
    typed :class:`AdmissionRejected` (with depth/capacity context) so
    callers back off instead of piling up unbounded work.
  - **Batching window** — each :meth:`ServingFrontend.pump` takes up to
    ``window`` admitted requests (highest priority first, then earliest
    deadline), coalesces compatible ``(op, n_bits, signed_out)``
    requests across tenants into ONE shared :class:`BbopInstr` each by
    concatenating their lanes, drains all groups through a single
    engine dispatch (heterogeneous wave fusion does the rest), and
    fans results back out to each ticket by lane slice — bit-exactly
    equal to dispatching each request alone.
  - **Deadlines** — absolute points on the *modeled* DRAM clock
    (:attr:`ServingFrontend.now_s`).  Expired requests are rejected
    with :class:`DeadlineExceeded` before dispatch; a wave whose every
    deadline passes mid-replay is abandoned at a super-round boundary
    through the engines' ``cancel`` hook; work that finishes past its
    deadline is rejected too, never silently completed late.
  - **Retry with backoff** — a dispatch that dies with
    :class:`~repro.core.fault.FaultExhaustedError` is retried up to
    ``max_retries`` times with exponential backoff × seeded jitter
    (the engine blacklists offenders between attempts, so retries
    genuinely repack around them).
  - **Circuit breaker + graceful degradation** — per-tenant
    CLOSED → OPEN → HALF_OPEN breaker.  Repeated terminal failures trip
    a tenant to the host-oracle fallback path
    (:func:`repro.train.serve.bbop_host_oracle` — the same oracle
    ``PumServeOffload`` answers from), which stays bit-exact; after a
    modeled cooldown the breaker half-opens and one probe wave decides
    whether DRAM service resumes.

Everything is deterministic under a fixed seed: the clock is the
engines' modeled DRAM seconds (plus explicit backoff/cooldown waits),
never wall time, so a soak run replays identically.

Thread model: :meth:`submit` is safe from any thread;
:meth:`pump`/:meth:`drain` execute dispatches synchronously on the
calling thread (the deterministic mode benchmarks and tests use), and
:meth:`start`/:meth:`stop` run the same pump loop on a background
worker so submitters only ever block on their own
:meth:`Ticket.result`.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bank import BbopInstr, cached_table
from repro.core.fault import FaultExhaustedError
from repro.core.isa import DispatchCancelled
from repro.core.telemetry import REGISTRY, active_tracer, spec_as_dict


class AdmissionRejected(RuntimeError):
    """The bounded admission queue is full: back off and resubmit.

    Carries the rejection context so callers (and incident records) see
    the pressure, not just the refusal."""

    def __init__(self, tenant: str, queue_depth: int, capacity: int):
        super().__init__(
            f"admission queue full ({queue_depth}/{capacity} pending): "
            f"request from tenant {tenant!r} rejected — back off and "
            f"resubmit")
        self.tenant = tenant
        self.queue_depth = int(queue_depth)
        self.capacity = int(capacity)


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before (or during) execution; the
    work was cancelled or its late result discarded."""

    def __init__(self, tenant: str, deadline_s: float, now_s: float,
                 where: str):
        super().__init__(
            f"deadline {deadline_s:.6g}s passed (modeled clock now "
            f"{now_s:.6g}s) {where}: request from tenant {tenant!r} "
            f"cancelled")
        self.tenant = tenant
        self.deadline_s = float(deadline_s)
        self.now_s = float(now_s)
        self.where = where


class BreakerState:
    CLOSED = "closed"        # normal service: requests dispatch to DRAM
    OPEN = "open"            # tripped: requests answer from host oracle
    HALF_OPEN = "half_open"  # cooldown over: one probe wave decides


class CircuitBreaker:
    """Per-tenant failure breaker (modeled-clock cooldown).

    ``threshold`` consecutive terminal dispatch failures trip
    CLOSED → OPEN; while OPEN the tenant's requests are shed to the
    host oracle.  ``allow()`` called after ``cooldown_s`` modeled
    seconds transitions OPEN → HALF_OPEN and admits one probe; the
    probe's wave succeeding closes the breaker, failing re-opens it
    (cooldown re-arms).
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 1e-3):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.state = BreakerState.CLOSED
        self.failures = 0          # consecutive terminal failures
        self.opened_at_s = 0.0
        self.trips = 0
        self.recoveries = 0

    def allow(self, now_s: float) -> bool:
        """May this tenant's request go to DRAM right now?"""
        if self.state == BreakerState.CLOSED:
            return True
        if self.state == BreakerState.OPEN:
            if now_s - self.opened_at_s >= self.cooldown_s:
                self.state = BreakerState.HALF_OPEN
                return True
            return False
        return True              # HALF_OPEN: probe in flight

    def record_success(self, now_s: float) -> bool:
        """A wave carrying this tenant completed; True if this closed a
        half-open breaker (a recovery)."""
        self.failures = 0
        if self.state == BreakerState.HALF_OPEN:
            self.state = BreakerState.CLOSED
            self.recoveries += 1
            return True
        return False

    def record_failure(self, now_s: float) -> bool:
        """A wave carrying this tenant terminally failed; True if this
        tripped (or re-tripped) the breaker OPEN."""
        self.failures += 1
        if self.state == BreakerState.HALF_OPEN or (
                self.state == BreakerState.CLOSED
                and self.failures >= self.threshold):
            self.state = BreakerState.OPEN
            self.opened_at_s = now_s
            self.trips += 1
            return True
        return False


class Ticket:
    """Future-style completion handle for one submitted request.

    Exactly-once resolution is enforced: a second resolve/reject raises
    (the zero-duplicated-ticket invariant the soak benchmark gates).
    """

    __slots__ = ("seq", "tenant", "op", "n_bits", "signed_out", "priority",
                 "deadline_s", "submitted_s", "resolved_s", "_event",
                 "_value", "_error", "_done", "via_host", "_lock")

    def __init__(self, seq: int, tenant: str, op: str, n_bits: int,
                 signed_out: bool, priority: int, deadline_s: float,
                 submitted_s: float):
        self.seq = seq
        self.tenant = tenant
        self.op = op
        self.n_bits = n_bits
        self.signed_out = signed_out
        self.priority = priority
        self.deadline_s = deadline_s
        self.submitted_s = submitted_s     # modeled clock at admission
        self.resolved_s = math.nan         # modeled clock at resolution
        self._event = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None
        self._done = False
        self.via_host = False    # answered by the host-oracle fallback?
        self._lock = threading.Lock()

    @property
    def done(self) -> bool:
        return self._done

    def result(self, timeout: Optional[float] = None):
        """Block (wall-clock) until resolved; returns the op's outputs
        (int64 array, tuple for multi-output ops) or raises the typed
        failure (:class:`DeadlineExceeded`, …).  In synchronous mode
        call :meth:`ServingFrontend.pump`/``drain`` first — nothing
        resolves tickets while no worker runs."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"ticket {self.seq} (tenant {self.tenant!r}) unresolved "
                f"after {timeout}s — is the frontend pumping?")
        if self._error is not None:
            raise self._error
        return self._value

    def _settle(self, value, error: Optional[BaseException]) -> None:
        with self._lock:
            if self._done:
                raise RuntimeError(
                    f"ticket {self.seq} (tenant {self.tenant!r}) resolved "
                    f"twice — fan-out bug")
            self._value = value
            self._error = error
            self._done = True
        self._event.set()


@dataclass
class _Request:
    """A submitted, admitted request waiting in the window queue."""
    ticket: Ticket
    operands: Tuple[np.ndarray, ...]
    attempts: int = 0


@dataclass
class FrontendStats:
    """Serving-layer counters (the engine's own Stats tiers sit below).

    ``admitted == completed + deadline_missed`` once drained — the
    zero-lost-ticket invariant; ``completed`` includes host-oracle
    answers (``host_fallbacks`` of them)."""

    submitted: int = 0           # submit() calls, incl. rejected
    admitted: int = 0            # tickets issued
    rejected: int = 0            # AdmissionRejected at submit
    completed: int = 0           # tickets resolved with a value
    deadline_missed: int = 0     # tickets rejected DeadlineExceeded
    host_fallbacks: int = 0      # completions answered by the oracle
    waves: int = 0               # engine dispatches that succeeded
    coalesced_instrs: int = 0    # BbopInstrs across those waves
    cancelled_waves: int = 0     # dispatches abandoned via cancel hook
    dispatch_failures: int = 0   # FaultExhaustedError from the engine
    retries: int = 0             # re-dispatch attempts after backoff
    backoff_s: float = 0.0       # modeled seconds slept in backoff
    breaker_trips: int = 0
    breaker_recoveries: int = 0

    _FIELD_SPEC = (
        ("submitted", "int"),
        ("admitted", "int"),
        ("rejected", "int"),
        ("completed", "int"),
        ("deadline_missed", "int"),
        ("host_fallbacks", "int"),
        ("waves", "int"),
        ("coalesced_instrs", "int"),
        ("cancelled_waves", "int"),
        ("dispatch_failures", "int"),
        ("retries", "int"),
        ("backoff_s", "float"),
        ("breaker_trips", "int"),
        ("breaker_recoveries", "int"),
    )

    def as_dict(self) -> Dict[str, object]:
        return spec_as_dict(self)


class ServingFrontend:
    """Multi-tenant admission/batching/degradation layer over one engine.

    Args:
        engine: anything with ``dispatch(queue, cancel=...)`` and a
            ``stats.total_latency_s`` modeled clock — normally a
            :class:`~repro.core.channel.SimdramChannel` (the default,
            created lazily), but the chip and bank engines work too.
        max_queue_depth: admission bound; :meth:`submit` raises
            :class:`AdmissionRejected` beyond it.
        window: max requests coalesced into one pump's shared wave.
        max_retries: re-dispatches after ``FaultExhaustedError`` before
            the wave is declared terminally failed.
        backoff_s / backoff_mult / jitter: retry backoff — attempt *k*
            sleeps ``backoff_s * backoff_mult**(k-1) * (1 + jitter*u)``
            modeled seconds, ``u`` drawn from the seeded rng.
        breaker_threshold / breaker_cooldown_s: per-tenant circuit
            breaker configuration (see :class:`CircuitBreaker`).
        seed: jitter rng seed (determinism under test).
    """

    def __init__(self, engine=None, *, max_queue_depth: int = 256,
                 window: int = 16, max_retries: int = 2,
                 backoff_s: float = 1e-4, backoff_mult: float = 2.0,
                 jitter: float = 0.25, breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 1e-3, seed: int = 0):
        if max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1")
        if window < 1:
            raise ValueError("window must be >= 1")
        if engine is None:
            from repro.core.channel import SimdramChannel
            engine = SimdramChannel()
        self.engine = engine
        self.style = getattr(engine, "style", "mig")
        self.max_queue_depth = max_queue_depth
        self.window = window
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_mult = backoff_mult
        self.jitter = jitter
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self._rng = np.random.default_rng(seed)
        self.stats = FrontendStats()
        self.breakers: Dict[str, CircuitBreaker] = {}
        self.now_s = 0.0                       # modeled DRAM clock
        self._eng_base = self._modeled_total()
        self._seq = 0
        self._pending: List[_Request] = []
        self._lock = threading.Lock()          # queue + clock + breakers
        self._have_work = threading.Condition(self._lock)
        self._worker: Optional[threading.Thread] = None
        self._stop = False

    # -- submission --------------------------------------------------------
    def submit(self, tenant: str, op: str, operands: Sequence, n_bits: int,
               *, deadline_s: Optional[float] = None, priority: int = 0,
               signed_out: bool = False) -> Ticket:
        """Admit one bbop request from ``tenant``; returns its
        :class:`Ticket` or raises :class:`AdmissionRejected` /
        ``KeyError`` (unknown op) / ``ValueError`` (operand mismatch).

        ``deadline_s`` is an ABSOLUTE modeled-clock point (compare
        :attr:`now_s`); ``None`` means no deadline.  Operands are flat
        integer arrays (one element per SIMD lane)."""
        spec, _, _ = cached_table(op, n_bits, self.style)
        if len(operands) != spec.n_operands:
            raise ValueError(
                f"{op} takes {spec.n_operands} operands, got "
                f"{len(operands)}")
        arrs = tuple(np.asarray(o).astype(np.int64).reshape(-1)
                     for o in operands)
        if len({a.shape[-1] for a in arrs}) > 1:
            raise ValueError("operand lengths differ")
        dl = math.inf if deadline_s is None else float(deadline_s)
        with self._lock:
            self.stats.submitted += 1
            if len(self._pending) >= self.max_queue_depth:
                self.stats.rejected += 1
                REGISTRY.counter("serving.rejected").inc()
                tr = active_tracer()
                if tr is not None:
                    tr.incident("admission_rejected", tenant=tenant,
                                queue_depth=len(self._pending),
                                capacity=self.max_queue_depth)
                raise AdmissionRejected(tenant, len(self._pending),
                                        self.max_queue_depth)
            self._seq += 1
            ticket = Ticket(self._seq, tenant, op, n_bits, signed_out,
                            priority, dl, self.now_s)
            self._pending.append(_Request(ticket, arrs))
            self.stats.admitted += 1
            REGISTRY.gauge("serving.queue_depth").set(len(self._pending))
            self._have_work.notify()
        return ticket

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._pending)

    # -- the pump ----------------------------------------------------------
    def pump(self) -> int:
        """Process one batching window synchronously; returns how many
        tickets were resolved (zero when the queue was empty)."""
        with self._lock:
            self._pending.sort(key=lambda r: (-r.ticket.priority,
                                              r.ticket.deadline_s,
                                              r.ticket.seq))
            batch = self._pending[:self.window]
            del self._pending[:self.window]
            REGISTRY.gauge("serving.queue_depth").set(len(self._pending))
        if not batch:
            return 0
        tr = active_tracer()
        root = (tr.begin("serving.pump", cat="serve", requests=len(batch),
                         tenants=len({r.ticket.tenant for r in batch}))
                if tr is not None else None)
        try:
            resolved = 0
            dispatchable: List[_Request] = []
            for r in batch:
                if r.ticket.deadline_s < self.now_s:
                    self._reject_deadline(r, "before dispatch")
                    resolved += 1
                elif not self._breaker(r.ticket.tenant).allow(self.now_s):
                    self._resolve_host(r)      # shed: breaker is OPEN
                    resolved += 1
                else:
                    dispatchable.append(r)
            resolved += self._dispatch_window(dispatchable)
            return resolved
        finally:
            if root is not None:
                tr.end(root)

    def drain(self) -> int:
        """Pump until the admission queue is empty; returns tickets
        resolved."""
        total = 0
        while True:
            n = self.pump()
            if n == 0 and not self.queue_depth:
                return total
            total += n

    # -- background worker -------------------------------------------------
    def start(self) -> None:
        """Run the pump loop on a background thread (true async mode:
        submitters block only on their own tickets)."""
        if self._worker is not None:
            raise RuntimeError("frontend worker already running")
        self._stop = False
        self._worker = threading.Thread(
            target=self._run, name="serving-frontend", daemon=True)
        self._worker.start()

    def stop(self) -> None:
        """Stop the worker after it finishes the in-flight window."""
        if self._worker is None:
            return
        with self._lock:
            self._stop = True
            self._have_work.notify()
        self._worker.join()
        self._worker = None

    def _run(self) -> None:
        while True:
            with self._lock:
                while not self._pending and not self._stop:
                    self._have_work.wait(0.05)
                if self._stop and not self._pending:
                    return
            self.pump()

    # -- internals ---------------------------------------------------------
    def _modeled_total(self) -> float:
        stats = getattr(self.engine, "stats", None)
        return float(getattr(stats, "total_latency_s", 0.0))

    def _advance_clock(self) -> None:
        total = self._modeled_total()
        self.now_s += total - self._eng_base
        self._eng_base = total

    def _sleep(self, seconds: float) -> None:
        self.now_s += seconds
        self.stats.backoff_s += seconds

    def _backoff(self, attempt: int) -> float:
        u = float(self._rng.random())
        return (self.backoff_s * self.backoff_mult ** (attempt - 1)
                * (1.0 + self.jitter * u))

    def _breaker(self, tenant: str) -> CircuitBreaker:
        br = self.breakers.get(tenant)
        if br is None:
            br = self.breakers[tenant] = CircuitBreaker(
                self.breaker_threshold, self.breaker_cooldown_s)
        return br

    def _finish(self, r: _Request, value,
                error: Optional[BaseException]) -> None:
        """Resolve one ticket exactly once, stamping its modeled
        completion time and the end-to-end latency histogram."""
        r.ticket.resolved_s = self.now_s
        REGISTRY.histogram("serving.latency_modeled_s").observe(
            self.now_s - r.ticket.submitted_s)
        r.ticket._settle(value, error)

    def _reject_deadline(self, r: _Request, where: str) -> None:
        self.stats.deadline_missed += 1
        REGISTRY.counter("serving.deadline_missed").inc()
        tr = active_tracer()
        if tr is not None:
            tr.incident("deadline_missed", tenant=r.ticket.tenant,
                        seq=r.ticket.seq, deadline_s=r.ticket.deadline_s,
                        now_s=self.now_s, where=where)
        self._finish(r, None, DeadlineExceeded(
            r.ticket.tenant, r.ticket.deadline_s, self.now_s, where))

    def _resolve_host(self, r: _Request) -> None:
        """Answer one request from the host oracle (bit-exact graceful
        degradation — no DRAM time is charged)."""
        from repro.train.serve import bbop_host_oracle
        value = bbop_host_oracle(r.ticket.op, r.ticket.n_bits, r.operands,
                                 signed_out=r.ticket.signed_out)
        r.ticket.via_host = True
        self.stats.host_fallbacks += 1
        self.stats.completed += 1
        REGISTRY.counter("serving.host_fallbacks").inc()
        self._finish(r, value, None)

    def _coalesce(self, reqs: Sequence[_Request]):
        """Group ``reqs`` by (op, n_bits, signed_out) and concatenate
        each group's lanes into ONE shared BbopInstr.  Returns the
        queue plus per-request ``(req, instr_index, lo, hi)`` fan-out
        slices."""
        groups: Dict[Tuple[str, int, bool], List[_Request]] = {}
        for r in reqs:
            key = (r.ticket.op, r.ticket.n_bits, r.ticket.signed_out)
            groups.setdefault(key, []).append(r)
        queue: List[BbopInstr] = []
        slices: List[Tuple[_Request, int, int, int]] = []
        for (op, n_bits, signed_out), members in groups.items():
            n_ops = len(members[0].operands)
            operands = tuple(
                np.concatenate([m.operands[j] for m in members], axis=-1)
                for j in range(n_ops))
            qi = len(queue)
            queue.append(BbopInstr(op, operands, n_bits,
                                   signed_out=signed_out))
            lo = 0
            for m in members:
                hi = lo + m.operands[0].shape[-1]
                slices.append((m, qi, lo, hi))
                lo = hi
        return queue, slices

    def _dispatch_window(self, reqs: List[_Request]) -> int:
        """Dispatch one coalesced window with retry/backoff; resolve
        every ticket exactly once.  Returns tickets resolved."""
        if not reqs:
            return 0
        tr = active_tracer()
        resolved = 0
        attempt = 0
        while True:
            live: List[_Request] = []
            for r in reqs:
                if r.ticket.deadline_s < self.now_s:
                    self._reject_deadline(r, "after backoff")
                    resolved += 1
                else:
                    live.append(r)
            reqs = live
            if not reqs:
                return resolved
            queue, slices = self._coalesce(reqs)
            max_deadline = max(r.ticket.deadline_s for r in reqs)
            clock0, base0 = self.now_s, self._modeled_total()
            cancel = None
            if not math.isinf(max_deadline):
                cancel = (lambda: clock0 + (self._modeled_total() - base0)
                          > max_deadline)
            try:
                if tr is not None:
                    with tr.span("serving.dispatch", cat="serve",
                                 instrs=len(queue), requests=len(reqs),
                                 attempt=attempt):
                        results = self.engine.dispatch(queue, cancel=cancel)
                else:
                    results = self.engine.dispatch(queue, cancel=cancel)
            except DispatchCancelled:
                self._advance_clock()
                self.stats.cancelled_waves += 1
                REGISTRY.counter("serving.cancelled_waves").inc()
                for r in reqs:
                    self._reject_deadline(r, "mid-dispatch (cancelled)")
                return resolved + len(reqs)
            except FaultExhaustedError as e:
                self._advance_clock()
                attempt += 1
                self.stats.dispatch_failures += 1
                if tr is not None:
                    tr.incident("serving_dispatch_failed", attempt=attempt,
                                requests=len(reqs), **e.context())
                if attempt <= self.max_retries:
                    self.stats.retries += 1
                    self._sleep(self._backoff(attempt))
                    continue
                return resolved + self._fail_window(reqs)
            self._advance_clock()
            self.stats.waves += 1
            self.stats.coalesced_instrs += len(queue)
            for r, qi, lo, hi in slices:
                out = results[qi]
                value = (tuple(np.asarray(o)[..., lo:hi] for o in out)
                         if isinstance(out, tuple)
                         else np.asarray(out)[..., lo:hi])
                if r.ticket.deadline_s < self.now_s:
                    self._reject_deadline(r, "on completion (late)")
                else:
                    self.stats.completed += 1
                    self._finish(r, value, None)
                resolved += 1
            for tenant in {r.ticket.tenant for r in reqs}:
                if self._breaker(tenant).record_success(self.now_s):
                    self.stats.breaker_recoveries += 1
                    REGISTRY.counter("serving.breaker_recoveries").inc()
                    if tr is not None:
                        tr.incident("breaker_closed", tenant=tenant,
                                    now_s=self.now_s)
            self._publish_breaker_gauge()
            return resolved

    def _fail_window(self, reqs: List[_Request]) -> int:
        """Terminal wave failure: mark every tenant's breaker, answer
        every ticket from the host oracle (still bit-exact)."""
        tr = active_tracer()
        for tenant in {r.ticket.tenant for r in reqs}:
            if self._breaker(tenant).record_failure(self.now_s):
                self.stats.breaker_trips += 1
                REGISTRY.counter("serving.breaker_trips").inc()
                if tr is not None:
                    tr.incident("breaker_open", tenant=tenant,
                                now_s=self.now_s,
                                failures=self._breaker(tenant).failures)
        self._publish_breaker_gauge()
        for r in reqs:
            self._resolve_host(r)
        return len(reqs)

    def _publish_breaker_gauge(self) -> None:
        REGISTRY.gauge("serving.breakers_open").set(sum(
            1 for b in self.breakers.values()
            if b.state != BreakerState.CLOSED))
